//! Training stack: parameter store, optimizer, metrics, trainer loop.

pub mod adam;
pub mod metrics;
pub mod params;
pub mod task;
pub mod trainer;

pub use adam::Adam;
pub use metrics::{EvalKind, EvalResult, MetricAcc};
pub use params::ParamStore;
pub use task::{Batch, TaskData};
pub use trainer::{RunResult, TrainConfig, Trainer};
