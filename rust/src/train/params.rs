//! Parameter store: rust-side owner of every model tensor.
//!
//! Initialized from the manifest's param specs (same init schemes the
//! python tests use), fed positionally to every executable, updated by the
//! optimizer from the gradients the train_step artifact returns. The class
//! embedding table (`q_table`, always last) doubles as the sampler's index
//! source, so samplers always quantize LIVE embeddings.

use anyhow::Result;
use xla::Literal;

use crate::runtime::{lit_f32, ParamSpec};
use crate::util::Rng;

/// Owner of every model tensor, in manifest order.
pub struct ParamStore {
    /// per-tensor shape/init specs (manifest order)
    pub specs: Vec<ParamSpec>,
    /// the flat tensors themselves (manifest order)
    pub tensors: Vec<Vec<f32>>,
}

impl ParamStore {
    /// Initialize every tensor from its manifest init scheme.
    pub fn init(specs: &[ParamSpec], seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tensors = specs
            .iter()
            .map(|s| {
                let n = s.numel();
                if s.init == "zeros" {
                    vec![0.0; n]
                } else if s.init == "ones" {
                    vec![1.0; n]
                } else if let Some(std) = s.init.strip_prefix("normal:") {
                    let std: f32 = std.parse().unwrap_or(0.02);
                    (0..n).map(|_| rng.normal_f32(std)).collect()
                } else {
                    panic!("unknown init scheme '{}'", s.init)
                }
            })
            .collect();
        ParamStore { specs: specs.to_vec(), tensors }
    }

    /// Positional literals for an executable call.
    pub fn literals(&self) -> Result<Vec<Literal>> {
        self.specs
            .iter()
            .zip(&self.tensors)
            .map(|(s, t)| lit_f32(t, &s.shape))
            .collect()
    }

    /// The class-embedding table [n_classes, d] — always the last param.
    pub fn q_table(&self) -> &[f32] {
        self.tensors.last().expect("empty param store")
    }

    /// Mutable class-embedding table (the MIDX-Learn harness writes it).
    pub fn q_table_mut(&mut self) -> &mut Vec<f32> {
        self.tensors.last_mut().expect("empty param store")
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True when the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total float count across all tensors.
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Global gradient norm (diagnostics).
    pub fn grad_norm(grads: &[Vec<f32>]) -> f32 {
        let s: f64 = grads
            .iter()
            .flat_map(|g| g.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum();
        s.sqrt() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "w".into(), shape: vec![4, 3], init: "normal:0.500000".into() },
            ParamSpec { name: "b".into(), shape: vec![3], init: "zeros".into() },
            ParamSpec { name: "g".into(), shape: vec![3], init: "ones".into() },
            ParamSpec { name: "q_table".into(), shape: vec![10, 3], init: "normal:0.1".into() },
        ]
    }

    #[test]
    fn init_schemes() {
        let p = ParamStore::init(&specs(), 1);
        assert_eq!(p.len(), 4);
        assert_eq!(p.tensors[0].len(), 12);
        assert!(p.tensors[1].iter().all(|&x| x == 0.0));
        assert!(p.tensors[2].iter().all(|&x| x == 1.0));
        // normal:0.5 should produce spread values
        let spread = p.tensors[0].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(spread > 0.1);
        assert_eq!(p.q_table().len(), 30);
        assert_eq!(p.total_params(), 12 + 3 + 3 + 30);
    }

    #[test]
    fn deterministic() {
        let a = ParamStore::init(&specs(), 7);
        let b = ParamStore::init(&specs(), 7);
        assert_eq!(a.tensors, b.tensors);
        let c = ParamStore::init(&specs(), 8);
        assert_ne!(a.tensors[0], c.tensors[0]);
    }

    #[test]
    fn literals_shape() {
        let p = ParamStore::init(&specs(), 1);
        let lits = p.literals().unwrap();
        assert_eq!(lits.len(), 4);
        assert_eq!(lits[0].array_shape().unwrap().dims(), &[4, 3]);
    }

    #[test]
    fn grad_norm_basic() {
        let g = vec![vec![3.0f32], vec![4.0f32]];
        assert!((ParamStore::grad_norm(&g) - 5.0).abs() < 1e-6);
    }
}
