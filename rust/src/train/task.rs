//! Task abstraction: binds a synthetic dataset to the fixed artifact shapes
//! and produces positional input literals for the executables.

use anyhow::Result;
use xla::Literal;

use crate::data::{BagBatch, LmCorpus, RecDataset, SeqBatch, XmcDataset};
use crate::data::lm::Split;
use crate::runtime::Dims;
use crate::train::metrics::EvalKind;
use crate::util::Rng;

/// A materialized batch, arch-dependent.
#[derive(Clone, Debug)]
pub enum Batch {
    /// sequence batch (LM / sequential recommendation)
    Seq(SeqBatch),
    /// sparse bag batch (extreme classification)
    Bag(BagBatch),
}

impl Batch {
    /// Per-query positive class ids (flattened Bq rows).
    pub fn targets(&self) -> &[i32] {
        match self {
            Batch::Seq(b) => &b.targets,
            Batch::Bag(b) => &b.targets,
        }
    }

    /// Encoder input literals, in manifest input order.
    pub fn input_literals(&self) -> Result<Vec<Literal>> {
        use crate::runtime::{lit_f32, lit_i32};
        match self {
            Batch::Seq(b) => Ok(vec![lit_i32(&b.tokens, &[b.b, b.t])?]),
            Batch::Bag(b) => Ok(vec![
                lit_i32(&b.feat_ids, &[b.b, b.s])?,
                lit_f32(&b.feat_vals, &[b.b, b.s])?,
            ]),
        }
    }

    /// Query rows this batch produces (B·T for sequences, B for bags).
    pub fn bq(&self) -> usize {
        match self {
            Batch::Seq(b) => b.b * b.t,
            Batch::Bag(b) => b.b,
        }
    }
}

/// Dataset + shapes, shared (read-only) between trainer and prefetcher.
pub enum TaskData {
    /// synthetic language-model corpus
    Lm {
        /// the generated corpus
        corpus: LmCorpus,
        /// artifact shapes the batches must match
        dims: Dims,
    },
    /// synthetic sequential-recommendation interactions
    Rec {
        /// the generated interactions
        data: RecDataset,
        /// artifact shapes the batches must match
        dims: Dims,
    },
    /// synthetic extreme-classification samples
    Xmc {
        /// the generated samples
        data: XmcDataset,
        /// artifact shapes the batches must match
        dims: Dims,
    },
}

impl TaskData {
    /// The artifact shapes this task feeds.
    pub fn dims(&self) -> &Dims {
        match self {
            TaskData::Lm { dims, .. } | TaskData::Rec { dims, .. } | TaskData::Xmc { dims, .. } => {
                dims
            }
        }
    }

    /// Which metric family evaluation uses for this task.
    pub fn eval_kind(&self) -> EvalKind {
        match self {
            TaskData::Lm { .. } => EvalKind::Perplexity,
            TaskData::Rec { .. } => EvalKind::RankingTopK,
            TaskData::Xmc { .. } => EvalKind::PrecisionK,
        }
    }

    /// Class frequencies in the training split (for the Unigram sampler).
    pub fn frequencies(&self) -> Vec<f32> {
        match self {
            TaskData::Lm { corpus, .. } => corpus.frequencies.clone(),
            TaskData::Rec { data, .. } => data.frequencies.clone(),
            TaskData::Xmc { data, .. } => data.frequencies.clone(),
        }
    }

    /// One random training batch matching the artifact shapes.
    pub fn train_batch(&self, rng: &mut Rng) -> Batch {
        match self {
            TaskData::Lm { corpus, dims } => {
                Batch::Seq(corpus.batch(Split::Train, dims.batch, dims.seq_len, rng))
            }
            TaskData::Rec { data, dims } => Batch::Seq(data.batch(dims.batch, dims.seq_len, rng)),
            TaskData::Xmc { data, dims } => {
                let idx: Vec<usize> =
                    (0..dims.batch).map(|_| rng.below(data.train.len())).collect();
                Batch::Bag(data.batch_from(&data.train, &idx))
            }
        }
    }

    /// Deterministic evaluation batches (validation or test).
    pub fn eval_batches(&self, test: bool) -> Vec<Batch> {
        match self {
            TaskData::Lm { corpus, dims } => {
                let split = if test { Split::Test } else { Split::Valid };
                corpus
                    .eval_batches(split, dims.batch, dims.seq_len)
                    .into_iter()
                    .map(Batch::Seq)
                    .collect()
            }
            TaskData::Rec { data, dims } => {
                let users = if test { data.test_users.clone() } else { data.valid_users.clone() };
                data.eval_batches(users, dims.batch, dims.seq_len)
                    .into_iter()
                    .map(Batch::Seq)
                    .collect()
            }
            TaskData::Xmc { data, dims } => {
                // carve validation off the head of the test set
                let pool = &data.test;
                let half = pool.len() / 2;
                let slice: Vec<usize> =
                    if test { (half..pool.len()).collect() } else { (0..half).collect() };
                slice
                    .chunks(dims.batch)
                    .filter(|c| c.len() == dims.batch)
                    .map(|c| Batch::Bag(data.batch_from(pool, c)))
                    .collect()
            }
        }
    }

    /// For ranking eval only the LAST position of each sequence row counts
    /// (leave-one-out protocol). Returns the flat query-row indices to score.
    pub fn eval_query_rows(&self, batch: &Batch) -> Vec<usize> {
        match (self, batch) {
            (TaskData::Rec { dims, .. }, Batch::Seq(_)) => {
                (0..dims.batch).map(|r| r * dims.seq_len + dims.seq_len - 1).collect()
            }
            _ => (0..batch.bq()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::lm::LmConfig;
    use crate::data::recsys::RecConfig;

    fn dims_seq() -> Dims {
        Dims { n_classes: 100, d: 8, batch: 4, seq_len: 6, m_neg: 3, bq: 24, ..Default::default() }
    }

    #[test]
    fn lm_task_shapes() {
        let corpus = LmCorpus::generate(LmConfig {
            vocab: 100,
            train_tokens: 3000,
            valid_tokens: 600,
            test_tokens: 600,
            ..Default::default()
        });
        let task = TaskData::Lm { corpus, dims: dims_seq() };
        let mut rng = Rng::new(1);
        let b = task.train_batch(&mut rng);
        assert_eq!(b.bq(), 24);
        assert_eq!(b.targets().len(), 24);
        assert_eq!(task.eval_kind(), EvalKind::Perplexity);
        assert!(!task.eval_batches(false).is_empty());
        assert_eq!(task.eval_query_rows(&b).len(), 24);
        assert_eq!(task.frequencies().len(), 100);
    }

    #[test]
    fn rec_task_last_position_rows() {
        let data = RecDataset::generate(RecConfig {
            n_items: 100,
            n_users: 60,
            seq_len: 7,
            pool: 32,
            ..Default::default()
        });
        let task = TaskData::Rec { data, dims: dims_seq() };
        let mut rng = Rng::new(2);
        let b = task.train_batch(&mut rng);
        let rows = task.eval_query_rows(&b);
        assert_eq!(rows, vec![5, 11, 17, 23]);
        assert_eq!(task.eval_kind(), EvalKind::RankingTopK);
    }
}
