//! The training loop: the system's hot path.
//!
//! Per step (adaptive sampler):
//!   1. `encode`   artifact: batch → query embeddings z [Bq, D]
//!   2. rust sampler: M negatives + log proposal probs per query
//!   3. `train_step` artifact: loss + gradients (through the L1 kernel)
//!   4. rust Adam: parameter update
//! The sampler's index is rebuilt from the live class embeddings once per
//! epoch (paper §4.4). The `Full` baseline skips 1–2 and runs the O(N)
//! `full_step` artifact instead.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};


use crate::coordinator::pipeline::Prefetcher;
use crate::runtime::{lit_f32, lit_i32, to_f32, to_scalar_f32, Engine, Executable, Manifest};
use crate::sampler::Sampler;
use crate::train::metrics::{EvalResult, MetricAcc};
use crate::train::task::{Batch, TaskData};
use crate::train::{Adam, ParamStore};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub lr: f32,
    pub seed: u64,
    /// cap on eval batches per pass (0 = all)
    pub eval_cap: usize,
    /// early-stopping patience in epochs (0 = off)
    pub patience: usize,
    /// prefetch depth for the batch pipeline
    pub prefetch: usize,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 6,
            steps_per_epoch: 120,
            lr: 2e-3,
            seed: 2024,
            eval_cap: 24,
            patience: 0,
            prefetch: 2,
            verbose: false,
        }
    }
}

/// Wall-clock breakdown of one run (for §Perf and the Table 1 comparison).
#[derive(Clone, Debug, Default)]
pub struct Timing {
    pub encode_s: f64,
    pub sample_s: f64,
    pub step_s: f64,
    pub update_s: f64,
    pub rebuild_s: f64,
    pub eval_s: f64,
    pub steps: usize,
}

impl Timing {
    pub fn per_step_ms(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        (self.encode_s + self.sample_s + self.step_s + self.update_s) * 1000.0
            / self.steps as f64
    }
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub sampler_name: String,
    pub model: String,
    /// mean train loss per epoch
    pub train_loss: Vec<f64>,
    /// validation metrics per epoch
    pub valid: Vec<EvalResult>,
    /// final test metrics (best-epoch parameters are NOT restored; the run
    /// reports the final-epoch model, matching the paper's protocol of
    /// early stopping on validation)
    pub test: EvalResult,
    pub timing: Timing,
}

pub struct Trainer {
    pub manifest: Manifest,
    engine: Engine,
    encode: Executable,
    train_step: Executable,
    eval_scores: Executable,
    full_step: Option<Executable>,
    pub params: ParamStore,
    adam: Adam,
    /// None ⇒ Full-softmax baseline
    sampler: Option<Box<dyn Sampler>>,
    cfg: TrainConfig,
    rng: Rng,
    timing: Timing,
}

impl Trainer {
    pub fn new(
        manifest: Manifest,
        sampler: Option<Box<dyn Sampler>>,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let engine = Engine::cpu()?;
        let encode = engine.load_hlo(&manifest.artifact_path("encode")?)?;
        let train_step = engine.load_hlo(&manifest.artifact_path("train_step")?)?;
        let eval_scores = engine.load_hlo(&manifest.artifact_path("eval_scores")?)?;
        let full_step = if sampler.is_none() {
            Some(engine.load_hlo(&manifest.artifact_path("full_step").map_err(|_| {
                anyhow!(
                    "model '{}' has no full_step artifact — Full baseline unavailable",
                    manifest.name
                )
            })?)?)
        } else {
            None
        };
        let params = ParamStore::init(&manifest.params, cfg.seed);
        let shapes: Vec<usize> = params.tensors.iter().map(|t| t.len()).collect();
        let adam = Adam::new(cfg.lr, &shapes);
        let rng = Rng::new(cfg.seed ^ 0xABCD);
        Ok(Trainer {
            manifest,
            engine,
            encode,
            train_step,
            eval_scores,
            full_step,
            params,
            adam,
            sampler,
            cfg,
            rng,
            timing: Timing::default(),
        })
    }

    pub fn sampler_name(&self) -> String {
        self.sampler.as_ref().map(|s| s.name().to_string()).unwrap_or_else(|| "full".into())
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Query embeddings for a batch (runs the encode artifact).
    pub fn encode_batch(&mut self, batch: &Batch) -> Result<Vec<f32>> {
        let mut args = self.params.literals()?;
        args.extend(batch.input_literals()?);
        let out = self.encode.run(&args)?;
        to_f32(&out[0])
    }

    /// One optimizer step on `batch`; returns the loss.
    pub fn train_on(&mut self, batch: &Batch) -> Result<f32> {
        let dims = self.manifest.dims.clone();
        let bq = dims.bq;
        let m = dims.m_neg;
        let d = dims.d;
        debug_assert_eq!(batch.bq(), bq);

        let loss;
        let grads: Vec<Vec<f32>>;
        if let Some(full) = &self.full_step {
            let t0 = Instant::now();
            let mut args = self.params.literals()?;
            args.extend(batch.input_literals()?);
            args.push(lit_i32(batch.targets(), &[bq])?);
            let out = full.run(&args)?;
            loss = to_scalar_f32(&out[0])?;
            grads = out[1..].iter().map(to_f32).collect::<Result<_>>()?;
            self.timing.step_s += t0.elapsed().as_secs_f64();
        } else {
            // 1. encode
            let t0 = Instant::now();
            let z = self.encode_batch(batch)?;
            self.timing.encode_s += t0.elapsed().as_secs_f64();

            // 2. sample
            let t1 = Instant::now();
            let sampler = self.sampler.as_mut().unwrap();
            let targets = batch.targets();
            let mut neg_ids = vec![0i32; bq * m];
            let mut log_q = vec![0.0f32; bq * m];
            let mut ids = vec![0u32; m];
            let mut lq = vec![0.0f32; m];
            for r in 0..bq {
                sampler.sample_into(
                    &z[r * d..(r + 1) * d],
                    targets[r] as u32,
                    &mut self.rng,
                    &mut ids,
                    &mut lq,
                );
                for j in 0..m {
                    neg_ids[r * m + j] = ids[j] as i32;
                }
                log_q[r * m..(r + 1) * m].copy_from_slice(&lq);
            }
            self.timing.sample_s += t1.elapsed().as_secs_f64();

            // 3. loss + grads through the L1 kernel
            let t2 = Instant::now();
            let mut args = self.params.literals()?;
            args.extend(batch.input_literals()?);
            args.push(lit_i32(targets, &[bq])?);
            args.push(lit_i32(&neg_ids, &[bq, m])?);
            args.push(lit_f32(&log_q, &[bq, m])?);
            let out = self.train_step.run(&args)?;
            loss = to_scalar_f32(&out[0])?;
            grads = out[1..].iter().map(to_f32).collect::<Result<_>>()?;
            self.timing.step_s += t2.elapsed().as_secs_f64();
        }

        // 4. update
        let t3 = Instant::now();
        self.adam.step(&mut self.params.tensors, &grads);
        self.timing.update_s += t3.elapsed().as_secs_f64();
        self.timing.steps += 1;
        Ok(loss)
    }

    /// Rebuild the sampler index from the live class embeddings.
    pub fn rebuild_sampler(&mut self) {
        if let Some(s) = self.sampler.as_mut() {
            let t0 = Instant::now();
            let dims = &self.manifest.dims;
            s.rebuild(self.params.q_table(), dims.n_classes, dims.d, &mut self.rng);
            self.timing.rebuild_s += t0.elapsed().as_secs_f64();
        }
    }

    /// Full evaluation pass. `test=false` → validation split.
    pub fn evaluate(&mut self, task: &TaskData, test: bool) -> Result<EvalResult> {
        let t0 = Instant::now();
        let mut acc = MetricAcc::new(task.eval_kind());
        let n = self.manifest.dims.n_classes;
        let mut batches = task.eval_batches(test);
        if self.cfg.eval_cap > 0 && batches.len() > self.cfg.eval_cap {
            batches.truncate(self.cfg.eval_cap);
        }
        for batch in &batches {
            let mut args = self.params.literals()?;
            args.extend(batch.input_literals()?);
            let out = self.eval_scores.run(&args)?;
            let scores = to_f32(&out[0])?; // [bq, n]
            let targets = batch.targets();
            for r in task.eval_query_rows(batch) {
                acc.add(&scores[r * n..(r + 1) * n], targets[r] as usize);
            }
        }
        self.timing.eval_s += t0.elapsed().as_secs_f64();
        Ok(acc.finish())
    }

    /// Run the full experiment loop.
    pub fn run(mut self, task: Arc<TaskData>) -> Result<RunResult> {
        let mut train_loss = Vec::new();
        let mut valid = Vec::new();
        let mut best = f64::INFINITY;
        let mut bad_epochs = 0usize;

        for epoch in 0..self.cfg.epochs {
            self.rebuild_sampler();

            // prefetch pipeline: batch generation overlaps the XLA calls
            let task_c = Arc::clone(&task);
            let seed = self.cfg.seed ^ (epoch as u64) << 16;
            let steps = self.cfg.steps_per_epoch;
            let prefetcher = Prefetcher::spawn(self.cfg.prefetch, steps, move |i| {
                let mut rng = Rng::new(seed.wrapping_add(i as u64 * 7919));
                task_c.train_batch(&mut rng)
            });

            let mut loss_sum = 0.0f64;
            let mut count = 0usize;
            for batch in prefetcher {
                loss_sum += self.train_on(&batch)? as f64;
                count += 1;
            }
            let mean_loss = loss_sum / count.max(1) as f64;
            train_loss.push(mean_loss);

            let ev = self.evaluate(&task, false)?;
            if self.cfg.verbose {
                let metrics: Vec<String> =
                    ev.values.iter().map(|(k, v)| format!("{k}={v:.4}")).collect();
                println!(
                    "[{} | {}] epoch {epoch}: loss={mean_loss:.4} {}",
                    self.manifest.name,
                    self.sampler_name(),
                    metrics.join(" ")
                );
            }
            let obj = ev.objective();
            valid.push(ev);

            if obj < best - 1e-6 {
                best = obj;
                bad_epochs = 0;
            } else {
                bad_epochs += 1;
                if self.cfg.patience > 0 && bad_epochs >= self.cfg.patience {
                    if self.cfg.verbose {
                        println!("early stop at epoch {epoch}");
                    }
                    break;
                }
            }
        }

        let test = self.evaluate(&task, true)?;
        Ok(RunResult {
            sampler_name: self.sampler_name(),
            model: self.manifest.name.clone(),
            train_loss,
            valid,
            test,
            timing: self.timing,
        })
    }

    pub fn timing(&self) -> &Timing {
        &self.timing
    }

    /// Mutable sampler access (used by the MIDX-Learn harness to install
    /// gradient-learned codebooks between epochs).
    pub fn sampler_mut(&mut self) -> Option<&mut (dyn Sampler + '_)> {
        self.sampler.as_deref_mut().map(|s| s as &mut (dyn Sampler + '_))
    }

    /// Manual-epoch API used by harnesses that interleave extra work
    /// (e.g. codebook learning) between epochs. Skips `rebuild_sampler` —
    /// callers control index refresh themselves.
    pub fn run_steps(&mut self, task: &TaskData, steps: usize, epoch_tag: u64) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        let mut rng = Rng::new(self.cfg.seed ^ epoch_tag.wrapping_mul(0x9E37));
        for _ in 0..steps {
            let batch = task.train_batch(&mut rng);
            loss_sum += self.train_on(&batch)? as f64;
        }
        Ok(loss_sum / steps.max(1) as f64)
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}
