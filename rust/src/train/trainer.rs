//! The training loop: the system's hot path.
//!
//! Per step (adaptive sampler):
//!   1. `encode`   artifact: batch → query embeddings z [Bq, D]
//!   2. rust sampler: M negatives + log proposal probs per query — batched
//!      across the whole [Bq, D] block by the multi-threaded sampling
//!      engine (`sampler::sample_batch_with` on the trainer's persistent
//!      pool), with per-query RNG streams so results are reproducible for
//!      any thread count
//!   3. `train_step` artifact: loss + gradients (through the L1 kernel)
//!   4. rust Adam: parameter update
//!
//! `run()` additionally software-pipelines the epoch: because sampling is
//! `&self` against an immutable core, step i's sample phase runs on the
//! trainer's persistent worker pool (`coordinator::pool::WorkerPool`, one
//! per run — workers stay parked between steps) while the main thread
//! issues the encode artifact call for step i+1 (`pipeline::overlap`). The
//! sampler's index is rebuilt from the live class embeddings once per epoch
//! (paper §4.4). The `Full` baseline skips 1–2 and runs the O(N)
//! `full_step` artifact instead.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::pipeline::{overlap, Prefetcher};
use crate::coordinator::pool::WorkerPool;
use crate::index::RefreshPolicy;
use crate::obs::log;
use crate::obs::metrics::hot;
use crate::runtime::{lit_f32, lit_i32, to_f32, to_scalar_f32, Engine, Executable, Manifest};
use crate::sampler::{batch::auto_threads, sample_batch_with, Sampler};
use crate::train::metrics::{EvalResult, MetricAcc};
use crate::train::task::{Batch, TaskData};
use crate::train::{Adam, ParamStore};
use crate::util::Rng;

/// Knobs of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// epochs to run (early stopping may cut this short)
    pub epochs: usize,
    /// optimizer steps per epoch
    pub steps_per_epoch: usize,
    /// Adam learning rate
    pub lr: f32,
    /// master seed (parameters, batches, sampling streams)
    pub seed: u64,
    /// cap on eval batches per pass (0 = all)
    pub eval_cap: usize,
    /// early-stopping patience in epochs (0 = off)
    pub patience: usize,
    /// prefetch depth for the batch pipeline
    pub prefetch: usize,
    /// sampling worker threads (0 = available parallelism)
    pub threads: usize,
    /// how the sampler index is refreshed between epochs (CLI `--refresh`);
    /// `Full` is the paper's once-per-epoch cold rebuild
    pub refresh: RefreshPolicy,
    /// write a servable sampler snapshot here after training (CLI
    /// `--export`); requires a MIDX-family sampler
    pub export: Option<String>,
    /// print per-epoch progress lines
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 6,
            steps_per_epoch: 120,
            lr: 2e-3,
            seed: 2024,
            eval_cap: 24,
            patience: 0,
            prefetch: 2,
            threads: 0,
            refresh: RefreshPolicy::Full,
            export: None,
            verbose: false,
        }
    }
}

/// Wall-clock breakdown of one run (for §Perf and the Table 1 comparison).
/// `sample_s` and `encode_s` are per-lane times; in the pipelined `run()`
/// loop they overlap in wall clock, so their sum can exceed elapsed time.
#[derive(Clone, Debug, Default)]
pub struct Timing {
    /// encode-artifact lane time
    pub encode_s: f64,
    /// sampling lane time
    pub sample_s: f64,
    /// train_step / full_step artifact time
    pub step_s: f64,
    /// Adam update time
    pub update_s: f64,
    /// cold sampler rebuilds (k-means retrain + index build)
    pub rebuild_s: f64,
    /// incremental index refreshes (drift scan + reassign + refine)
    pub refresh_s: f64,
    /// evaluation passes
    pub eval_s: f64,
    /// optimizer steps taken
    pub steps: usize,
    /// cold rebuilds performed
    pub full_rebuilds: usize,
    /// incremental refreshes performed
    pub incr_refreshes: usize,
    /// classes whose bucket changed across all incremental refreshes
    pub reassigned: usize,
}

impl Timing {
    /// Mean wall-clock per optimizer step (all four step phases), in ms.
    pub fn per_step_ms(&self) -> f64 {
        if self.steps == 0 {
            return 0.0;
        }
        (self.encode_s + self.sample_s + self.step_s + self.update_s) * 1000.0
            / self.steps as f64
    }
}

/// Everything one experiment run produces.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// sampler identifier ("full" for the O(N) baseline)
    pub sampler_name: String,
    /// artifact model name
    pub model: String,
    /// mean train loss per epoch
    pub train_loss: Vec<f64>,
    /// validation metrics per epoch
    pub valid: Vec<EvalResult>,
    /// final test metrics (best-epoch parameters are NOT restored; the run
    /// reports the final-epoch model, matching the paper's protocol of
    /// early stopping on validation)
    pub test: EvalResult,
    /// wall-clock breakdown
    pub timing: Timing,
}

/// The training loop driver: owns the executables, parameters, optimizer,
/// sampler (plus its worker pool) and the timing ledger for one run.
pub struct Trainer {
    /// the model's artifact manifest (shapes, params, executable paths)
    pub manifest: Manifest,
    engine: Engine,
    encode: Executable,
    train_step: Executable,
    eval_scores: Executable,
    full_step: Option<Executable>,
    /// live model parameters (the last tensor is the class table)
    pub params: ParamStore,
    adam: Adam,
    /// None ⇒ Full-softmax baseline
    sampler: Option<Box<dyn Sampler>>,
    cfg: TrainConfig,
    /// resolved sampling thread count (cfg.threads, 0 → hardware)
    threads: usize,
    /// persistent sampling worker pool, one per run (None for the Full
    /// baseline, which never samples): workers stay parked between steps,
    /// so per-step batches pay a condvar wake, not a thread spawn
    pool: Option<WorkerPool>,
    rng: Rng,
    timing: Timing,
}

impl Trainer {
    /// Load and compile the model's executables and initialize parameters,
    /// optimizer, and (for sampled runs with `threads > 1`) the persistent
    /// sampling worker pool. `sampler: None` selects the Full baseline.
    pub fn new(
        manifest: Manifest,
        sampler: Option<Box<dyn Sampler>>,
        cfg: TrainConfig,
    ) -> Result<Self> {
        let engine = Engine::cpu()?;
        let encode = engine.load_hlo(&manifest.artifact_path("encode")?)?;
        let train_step = engine.load_hlo(&manifest.artifact_path("train_step")?)?;
        let eval_scores = engine.load_hlo(&manifest.artifact_path("eval_scores")?)?;
        let full_step = if sampler.is_none() {
            Some(engine.load_hlo(&manifest.artifact_path("full_step").map_err(|_| {
                anyhow!(
                    "model '{}' has no full_step artifact — Full baseline unavailable",
                    manifest.name
                )
            })?)?)
        } else {
            None
        };
        let params = ParamStore::init(&manifest.params, cfg.seed);
        let shapes: Vec<usize> = params.tensors.iter().map(|t| t.len()).collect();
        let adam = Adam::new(cfg.lr, &shapes);
        let rng = Rng::new(cfg.seed ^ 0xABCD);
        let threads = if cfg.threads == 0 { auto_threads() } else { cfg.threads };
        // the pool lives as long as the trainer: --threads picks the worker
        // count once here, not per sample_batch call. T = 1 (and the Full
        // baseline) never dispatches, so spawn no workers at all —
        // sample_batch_with runs inline when handed None.
        let pool =
            if sampler.is_some() && threads > 1 { Some(WorkerPool::new(threads)) } else { None };
        Ok(Trainer {
            manifest,
            engine,
            encode,
            train_step,
            eval_scores,
            full_step,
            params,
            adam,
            sampler,
            cfg,
            threads,
            pool,
            rng,
            timing: Timing::default(),
        })
    }

    /// Sampler identifier ("full" for the O(N) baseline).
    pub fn sampler_name(&self) -> String {
        self.sampler.as_ref().map(|s| s.name().to_string()).unwrap_or_else(|| "full".into())
    }

    /// The PJRT engine (for harnesses that load extra executables).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Resolved sampling worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The run-lifetime sampling worker pool (None for the Full baseline).
    pub fn pool(&self) -> Option<&WorkerPool> {
        self.pool.as_ref()
    }

    /// Query embeddings for a batch (runs the encode artifact). `&self`:
    /// safe to call while the sample phase runs on worker threads.
    pub fn encode_batch(&self, batch: &Batch) -> Result<Vec<f32>> {
        let mut args = self.params.literals()?;
        args.extend(batch.input_literals()?);
        let out = self.encode.run(&args)?;
        to_f32(&out[0])
    }

    /// Shared prep for a sample phase: the per-step stream base (drawn on
    /// the main thread, in step order, so runs stay reproducible while
    /// draws stay schedule-independent), u32 positives, and zeroed [B, M]
    /// id / log q buffers. Single source of truth for the seed scheme and
    /// the positive-encoding convention, used by both the sequential and
    /// the pipelined path.
    fn prepare_sample(&mut self, targets: &[i32]) -> (u64, Vec<u32>, Vec<u32>, Vec<f32>) {
        let m = self.manifest.dims.m_neg;
        let b = targets.len();
        let seed = self.rng.next_u64();
        let positives: Vec<u32> = targets.iter().map(|&t| t as u32).collect();
        (seed, positives, vec![0u32; b * m], vec![0.0f32; b * m])
    }

    /// Batched sample phase for an encoded batch: M negatives + log q per
    /// query, drawn by the multi-threaded engine. Returns ([Bq, M] ids as
    /// i32 for the artifact ABI, [Bq, M] log q).
    fn sample_negatives(&mut self, z: &[f32], targets: &[i32]) -> (Vec<i32>, Vec<f32>) {
        let (m, d) = (self.manifest.dims.m_neg, self.manifest.dims.d);
        let (seed, positives, mut ids, mut log_q) = self.prepare_sample(targets);
        let t1 = Instant::now();
        let sampler = self.sampler.as_ref().expect("sample_negatives without sampler");
        sample_batch_with(
            self.pool.as_ref(),
            sampler.core(),
            z,
            d,
            &positives,
            m,
            seed,
            self.threads,
            &mut ids,
            &mut log_q,
        );
        self.timing.sample_s += t1.elapsed().as_secs_f64();
        (to_neg_ids(&ids), log_q)
    }

    /// Steps 3–4 for the sampled path: train_step artifact + Adam update.
    fn apply_sampled_step(
        &mut self,
        batch: &Batch,
        neg_ids: &[i32],
        log_q: &[f32],
    ) -> Result<f32> {
        let dims = self.manifest.dims.clone();
        let (bq, m) = (dims.bq, dims.m_neg);
        let t2 = Instant::now();
        let mut args = self.params.literals()?;
        args.extend(batch.input_literals()?);
        args.push(lit_i32(batch.targets(), &[bq])?);
        args.push(lit_i32(neg_ids, &[bq, m])?);
        args.push(lit_f32(log_q, &[bq, m])?);
        let out = self.train_step.run(&args)?;
        let loss = to_scalar_f32(&out[0])?;
        let grads: Vec<Vec<f32>> = out[1..].iter().map(to_f32).collect::<Result<_>>()?;
        self.timing.step_s += t2.elapsed().as_secs_f64();

        let t3 = Instant::now();
        self.adam.step(&mut self.params.tensors, &grads);
        self.timing.update_s += t3.elapsed().as_secs_f64();
        self.timing.steps += 1;
        Ok(loss)
    }

    /// One optimizer step on `batch`; returns the loss. Sequential
    /// (non-pipelined) path, used by `run_steps` and the Full baseline.
    pub fn train_on(&mut self, batch: &Batch) -> Result<f32> {
        debug_assert_eq!(batch.bq(), self.manifest.dims.bq);

        if let Some(full) = &self.full_step {
            let bq = self.manifest.dims.bq;
            let t0 = Instant::now();
            let mut args = self.params.literals()?;
            args.extend(batch.input_literals()?);
            args.push(lit_i32(batch.targets(), &[bq])?);
            let out = full.run(&args)?;
            let loss = to_scalar_f32(&out[0])?;
            let grads: Vec<Vec<f32>> = out[1..].iter().map(to_f32).collect::<Result<_>>()?;
            self.timing.step_s += t0.elapsed().as_secs_f64();

            let t3 = Instant::now();
            self.adam.step(&mut self.params.tensors, &grads);
            self.timing.update_s += t3.elapsed().as_secs_f64();
            self.timing.steps += 1;
            return Ok(loss);
        }

        // 1. encode
        let t0 = Instant::now();
        let z = self.encode_batch(batch)?;
        self.timing.encode_s += t0.elapsed().as_secs_f64();

        // 2. sample (batched engine)
        let (neg_ids, log_q) = self.sample_negatives(&z, batch.targets());

        // 3–4. loss + grads + update
        self.apply_sampled_step(batch, &neg_ids, &log_q)
    }

    /// Refresh the sampler index from the live class embeddings under the
    /// configured [`RefreshPolicy`] (`TrainConfig::refresh`): a cold
    /// rebuild books into `timing.rebuild_s`, an incremental refresh into
    /// `timing.refresh_s` + the refresh counters.
    pub fn rebuild_sampler(&mut self) {
        let policy = self.cfg.refresh;
        if let Some(s) = self.sampler.as_mut() {
            let t0 = Instant::now();
            let dims = &self.manifest.dims;
            let table = self.params.q_table();
            let outcome = s.rebuild_with(table, dims.n_classes, dims.d, &mut self.rng, &policy);
            let dt = t0.elapsed().as_secs_f64();
            if outcome.full {
                self.timing.rebuild_s += dt;
                self.timing.full_rebuilds += 1;
            } else {
                self.timing.refresh_s += dt;
                self.timing.incr_refreshes += 1;
                self.timing.reassigned += outcome.reassigned;
            }
        }
    }

    /// Full evaluation pass. `test=false` → validation split.
    pub fn evaluate(&mut self, task: &TaskData, test: bool) -> Result<EvalResult> {
        let t0 = Instant::now();
        let mut acc = MetricAcc::new(task.eval_kind());
        let n = self.manifest.dims.n_classes;
        let mut batches = task.eval_batches(test);
        if self.cfg.eval_cap > 0 && batches.len() > self.cfg.eval_cap {
            batches.truncate(self.cfg.eval_cap);
        }
        for batch in &batches {
            let mut args = self.params.literals()?;
            args.extend(batch.input_literals()?);
            let out = self.eval_scores.run(&args)?;
            let scores = to_f32(&out[0])?; // [bq, n]
            let targets = batch.targets();
            for r in task.eval_query_rows(batch) {
                acc.add(&scores[r * n..(r + 1) * n], targets[r] as usize);
            }
        }
        self.timing.eval_s += t0.elapsed().as_secs_f64();
        Ok(acc.finish())
    }

    /// One pipelined epoch of the sampled path: while worker threads draw
    /// step i's negatives against the immutable sampler core, the main
    /// thread runs step i+1's encode artifact call.
    ///
    /// Pipelining semantics: the encode for step i+1 runs BEFORE step i's
    /// Adam update, so step i+1's proposal sees query embeddings that are
    /// one optimizer step stale (the sequential `train_on`/`run_steps`
    /// path encodes after the update, so the two paths draw different
    /// negatives for the same seed). This is sound for the same reason the
    /// paper's once-per-epoch index rebuild is (§4.4): the proposal may lag
    /// the parameters arbitrarily as long as each draw's `log_q` matches
    /// the distribution actually sampled — which it does, both being
    /// computed from the same z against the same core. The `train_step`
    /// artifact re-encodes internally from CURRENT parameters, so loss and
    /// gradients are never stale.
    fn run_sampled_epoch(&mut self, prefetcher: Prefetcher<Batch>) -> Result<(f64, usize)> {
        let dims = self.manifest.dims.clone();
        let (m, d) = (dims.m_neg, dims.d);
        let mut prefetcher = prefetcher;

        let mut cur = prefetcher.next();
        let mut z_cur = match &cur {
            Some(b) => {
                let t0 = Instant::now();
                let z = self.encode_batch(b)?;
                self.timing.encode_s += t0.elapsed().as_secs_f64();
                Some(z)
            }
            None => None,
        };

        let mut loss_sum = 0.0f64;
        let mut count = 0usize;
        loop {
            let batch = match cur.take() {
                Some(b) => b,
                None => break,
            };
            let z = z_cur.take().expect("encode pipelined with batch");
            let next = prefetcher.next();

            let (seed, positives, mut neg_u32, mut log_q) = self.prepare_sample(batch.targets());
            // leave one core to the concurrent encode lane when it runs
            // (lane cap per call; the pool itself keeps all its workers)
            let threads = if next.is_some() {
                self.threads.saturating_sub(1).max(1)
            } else {
                self.threads
            };
            // the worker lane borrows the Sync core, not the &mut-style
            // adapter — that is exactly what the shared-core split buys us
            let core = self.sampler.as_deref().expect("sampled epoch without sampler").core();
            let pool = self.pool.as_ref();

            // lane A (workers): sample step i | lane B (main): encode step i+1
            let (sample_elapsed, encoded_next) = overlap(
                || {
                    let t = Instant::now();
                    sample_batch_with(
                        pool, core, &z, d, &positives, m, seed, threads, &mut neg_u32, &mut log_q,
                    );
                    t.elapsed().as_secs_f64()
                },
                || {
                    next.as_ref().map(|nb| {
                        let t = Instant::now();
                        let r = self.encode_batch(nb);
                        (r, t.elapsed().as_secs_f64())
                    })
                },
            );
            self.timing.sample_s += sample_elapsed;
            let z_next = match encoded_next {
                Some((r, enc_elapsed)) => {
                    self.timing.encode_s += enc_elapsed;
                    Some(r?)
                }
                None => None,
            };

            let neg_ids = to_neg_ids(&neg_u32);
            loss_sum += self.apply_sampled_step(&batch, &neg_ids, &log_q)? as f64;
            count += 1;

            cur = next;
            z_cur = z_next;
        }
        Ok((loss_sum, count))
    }

    /// Run the full experiment loop.
    pub fn run(mut self, task: Arc<TaskData>) -> Result<RunResult> {
        let mut train_loss = Vec::new();
        let mut valid = Vec::new();
        let mut best = f64::INFINITY;
        let mut bad_epochs = 0usize;

        for epoch in 0..self.cfg.epochs {
            let before = (
                self.timing.sample_s,
                self.timing.encode_s,
                self.timing.rebuild_s + self.timing.refresh_s,
            );
            self.rebuild_sampler();

            // prefetch pipeline: batch generation overlaps the XLA calls
            let task_c = Arc::clone(&task);
            let seed = self.cfg.seed ^ (epoch as u64) << 16;
            let steps = self.cfg.steps_per_epoch;
            let prefetcher = Prefetcher::spawn(self.cfg.prefetch, steps, move |i| {
                let mut rng = Rng::new(seed.wrapping_add(i as u64 * 7919));
                task_c.train_batch(&mut rng)
            });

            let (loss_sum, count) = if self.sampler.is_some() {
                self.run_sampled_epoch(prefetcher)?
            } else {
                let mut loss_sum = 0.0f64;
                let mut count = 0usize;
                for batch in prefetcher {
                    loss_sum += self.train_on(&batch)? as f64;
                    count += 1;
                }
                (loss_sum, count)
            };
            let mean_loss = loss_sum / count.max(1) as f64;
            train_loss.push(mean_loss);
            self.record_epoch_metrics(before, epoch, mean_loss);

            let ev = self.evaluate(&task, false)?;
            if self.cfg.verbose {
                let metrics: Vec<String> =
                    ev.values.iter().map(|(k, v)| format!("{k}={v:.4}")).collect();
                println!(
                    "[{} | {}] epoch {epoch}: loss={mean_loss:.4} {}",
                    self.manifest.name,
                    self.sampler_name(),
                    metrics.join(" ")
                );
            }
            let obj = ev.objective();
            valid.push(ev);

            if obj < best - 1e-6 {
                best = obj;
                bad_epochs = 0;
            } else {
                bad_epochs += 1;
                if self.cfg.patience > 0 && bad_epochs >= self.cfg.patience {
                    if self.cfg.verbose {
                        println!("early stop at epoch {epoch}");
                    }
                    break;
                }
            }
        }

        let test = self.evaluate(&task, true)?;
        if let Some(path) = self.cfg.export.clone() {
            // refresh the index from the FINAL embeddings first, so the
            // exported core serves what the run actually learned (the
            // last in-loop rebuild saw the start-of-epoch table)
            self.rebuild_sampler();
            self.export_snapshot(&path)?;
        }
        Ok(RunResult {
            sampler_name: self.sampler_name(),
            model: self.manifest.name.clone(),
            train_loss,
            valid,
            test,
            timing: self.timing,
        })
    }

    /// Export the current sampler core + class embeddings as a servable
    /// snapshot (`TrainConfig::export`, CLI `--export`). Errors for the
    /// Full baseline and for samplers without a serializable core
    /// (everything outside the MIDX family and the static samplers).
    pub fn export_snapshot(&self, path: &str) -> Result<()> {
        let dims = &self.manifest.dims;
        let sampler = self.sampler.as_ref().ok_or_else(|| {
            anyhow!("--export requires a sampler (the Full baseline has no index to serve)")
        })?;
        let snap = sampler
            .snapshot(self.params.q_table(), dims.n_classes, dims.d)
            .ok_or_else(|| {
                anyhow!(
                    "sampler '{}' has no servable snapshot (exportable: midx-pq, midx-rq, \
                     exact-midx, uniform, unigram)",
                    sampler.name()
                )
            })?;
        snap.write(std::path::Path::new(path))?;
        if self.cfg.verbose {
            println!(
                "exported servable snapshot to {path} ({} classes, {} bytes)",
                dims.n_classes,
                snap.size_bytes()
            );
        }
        Ok(())
    }

    /// Book one epoch's phase-time deltas into the process-wide metrics
    /// registry (`train_epoch_{sample,encode,refresh}_us` histograms +
    /// `train_epochs_total`) and emit a debug-level structured epoch line.
    fn record_epoch_metrics(&self, before: (f64, f64, f64), epoch: usize, mean_loss: f64) {
        let d_sample = self.timing.sample_s - before.0;
        let d_encode = self.timing.encode_s - before.1;
        let d_refresh = self.timing.rebuild_s + self.timing.refresh_s - before.2;
        let us = |s: f64| (s.max(0.0) * 1e6) as u64;
        let h = hot();
        h.train_sample_us.record(us(d_sample));
        h.train_encode_us.record(us(d_encode));
        h.train_refresh_us.record(us(d_refresh));
        h.train_epochs.inc();
        log::debug(&format!(
            "epoch {epoch}: loss={mean_loss:.4} sample={d_sample:.3}s \
             encode={d_encode:.3}s refresh={d_refresh:.3}s"
        ));
    }

    /// The run's wall-clock ledger so far.
    pub fn timing(&self) -> &Timing {
        &self.timing
    }

    /// Mutable sampler access (used by the MIDX-Learn harness to install
    /// gradient-learned codebooks between epochs).
    pub fn sampler_mut(&mut self) -> Option<&mut (dyn Sampler + '_)> {
        self.sampler.as_deref_mut().map(|s| s as &mut (dyn Sampler + '_))
    }

    /// Manual-epoch API used by harnesses that interleave extra work
    /// (e.g. codebook learning) between epochs. Skips `rebuild_sampler` —
    /// callers control index refresh themselves.
    pub fn run_steps(&mut self, task: &TaskData, steps: usize, epoch_tag: u64) -> Result<f64> {
        let mut loss_sum = 0.0f64;
        let mut rng = Rng::new(self.cfg.seed ^ epoch_tag.wrapping_mul(0x9E37));
        for _ in 0..steps {
            let batch = task.train_batch(&mut rng);
            loss_sum += self.train_on(&batch)? as f64;
        }
        Ok(loss_sum / steps.max(1) as f64)
    }

    /// The run's configuration.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}

/// u32 draw ids → the i32 the artifact ABI expects.
fn to_neg_ids(ids: &[u32]) -> Vec<i32> {
    ids.iter().map(|&x| x as i32).collect()
}
