//! In-tree micro-benchmark harness (offline environment — no criterion).
//!
//! `cargo bench` runs each `benches/*.rs` as a plain binary (harness = false
//! in Cargo.toml); those binaries use this module for warmup, repetition and
//! robust statistics, printing one line per case in a stable, grep-able
//! format:
//!
//! ```text
//! bench <group>/<name>  median=…  mean=…  p10=…  p90=…  iters=…
//! ```

use std::time::Instant;

/// Robust timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// case name ("group/name")
    pub name: String,
    /// median per-iteration time in ns
    pub median_ns: f64,
    /// mean per-iteration time in ns
    pub mean_ns: f64,
    /// 10th-percentile per-iteration time in ns
    pub p10_ns: f64,
    /// 90th-percentile per-iteration time in ns
    pub p90_ns: f64,
    /// total iterations measured
    pub iters: usize,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

impl BenchStats {
    /// Print the standard grep-able one-line summary.
    pub fn print(&self) {
        println!(
            "bench {:<44} median={:<10} mean={:<10} p10={:<10} p90={:<10} iters={}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters
        );
    }
}

/// Benchmark a closure: auto-calibrated iteration count targeting
/// ~`budget_ms` of total measurement time, after warmup.
pub fn bench_ms<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchStats {
    // Warmup + calibration: find per-iter cost.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed().as_nanos().max(1) as f64;
    let budget_ns = (budget_ms as f64) * 1e6;
    // target ≥ 10 samples, each sample possibly batching multiple iters
    let samples = 15usize;
    let per_sample_ns = budget_ns / samples as f64;
    let batch = ((per_sample_ns / first).floor() as usize).clamp(1, 1_000_000);

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        median_ns: times[times.len() / 2],
        mean_ns: times.iter().sum::<f64>() / times.len() as f64,
        p10_ns: times[times.len() / 10],
        p90_ns: times[times.len() * 9 / 10],
        iters: batch * samples,
    };
    stats.print();
    stats
}

/// Time a single execution (for expensive end-to-end cases).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    let ns = t.elapsed().as_nanos() as f64;
    println!("bench {:<44} once={}", name, fmt_ns(ns));
    (out, ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let s = bench_ms("test/noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters > 0);
        assert!(s.p10_ns <= s.median_ns + 1.0);
        assert!(s.median_ns <= s.p90_ns + 1.0);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
