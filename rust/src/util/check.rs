//! Lightweight property-testing harness (offline environment — no proptest).
//!
//! `for_all` runs a property over many seeded random cases and reports the
//! first failing seed, so failures are reproducible (`CASES` env var scales
//! the sweep). No shrinking — generators are kept small instead.

use super::rng::Rng;

/// Number of cases per property (override with env `MIDX_PROP_CASES`).
pub fn num_cases() -> u64 {
    std::env::var("MIDX_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop(rng, case_index)` for `num_cases()` seeded cases; panic with the
/// failing seed on the first error.
pub fn for_all<F: FnMut(&mut Rng, u64) -> Result<(), String>>(name: &str, mut prop: F) {
    for case in 0..num_cases() {
        let seed = 0x5EED_0000 + case;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two floats are close; returns Err for use inside properties.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Random embedding matrix [n, d] with entries ~ N(0, std).
pub fn rand_matrix(rng: &mut Rng, n: usize, d: usize, std: f32) -> Vec<f32> {
    (0..n * d).map(|_| rng.normal_f32(std)).collect()
}

/// Random strictly-positive weight vector.
pub fn rand_weights(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 0.99 + 0.01).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_passes() {
        for_all("trivial", |rng, _| {
            let x = rng.next_f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn for_all_reports_failure() {
        for_all("fails", |rng, _| {
            if rng.next_f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
