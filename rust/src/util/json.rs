//! Minimal JSON parser/writer (offline environment — no serde_json).
//!
//! Covers the full JSON grammar we exchange with the python AOT path
//! (`artifacts/*/manifest.json`, `artifacts/index.json`) plus report output:
//! objects, arrays, strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (stored as f64)
    Num(f64),
    /// string
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    /// Object member lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Borrow as a string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// Borrow as an array, if this is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Borrow as an object, if this is one.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field` chain lookup with a readable error.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    /// Collect a numeric array into f32s (None if this is not an array of
    /// numbers) — the serve protocol's query-vector accessor.
    pub fn f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }
}

/// Build a JSON array from f32 values (stored as JSON numbers).
pub fn from_f32s(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Build a JSON array from u32 ids (stored as JSON numbers).
pub fn from_u32s(xs: &[u32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (valid utf8 passes through)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

/// Serialize a JSON value onto `out` (compact, sorted object keys).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{
 "name": "lm_ptb_lstm",
 "dims": {"n_classes": 2000, "d": 64},
 "params": [{"name": "tok_emb", "shape": [2000, 64], "init": "normal:0.125000"}],
 "flag": true, "none": null, "neg": -1.5e2
}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "lm_ptb_lstm");
        assert_eq!(j.get("dims").unwrap().get("d").unwrap().as_usize().unwrap(), 64);
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("shape").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("neg").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(j.get("none").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,"x\n\"y\""],"b":{"c":false}}"#;
        let j = Json::parse(s).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn numeric_array_helpers() {
        let xs = [0.5f32, -1.25, 3.0];
        let j = from_f32s(&xs);
        assert_eq!(j.f32_vec().unwrap(), xs.to_vec());
        let ids = from_u32s(&[7, 0, 42]);
        assert_eq!(ids.to_string(), "[7,0,42]");
        assert_eq!(Json::parse("[1,\"x\"]").unwrap().f32_vec(), None);
        assert_eq!(Json::Str("nope".into()).f32_vec(), None);
    }
}
