//! Small numeric helpers shared across samplers, metrics and stats.

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than a naive fold on
    // the scalar CPU backend and keeps error growth modest.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Squared euclidean distance.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Numerically stable log-sum-exp.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| ((x - m) as f64).exp()).sum();
    m + (s.ln() as f32)
}

/// In-place softmax; returns the log partition function.
pub fn softmax_inplace(xs: &mut [f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0.0f64;
    for x in xs.iter_mut() {
        let e = ((*x - m) as f64).exp();
        *x = e as f32;
        s += e;
    }
    let inv = (1.0 / s) as f32;
    for x in xs.iter_mut() {
        *x *= inv;
    }
    m + (s.ln() as f32)
}

/// Indices of the k largest values (descending). O(n log k).
pub fn top_k(xs: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Rev(f32, u32);
    impl Eq for Rev {}
    impl PartialOrd for Rev {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Rev {
        fn cmp(&self, o: &Self) -> Ordering {
            // min-heap on value; on ties evict the larger index so the
            // lowest indices win deterministically
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(xs.len());
    let mut heap: BinaryHeap<Rev> = BinaryHeap::with_capacity(k + 1);
    for (i, &x) in xs.iter().enumerate() {
        heap.push(Rev(x, i as u32));
        if heap.len() > k {
            heap.pop();
        }
    }
    let mut out: Vec<(f32, u32)> = heap.into_iter().map(|r| (r.0, r.1)).collect();
    out.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    out.into_iter().map(|(_, i)| i).collect()
}

/// argmax with deterministic tie-break (lowest index).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// l2 norm.
pub fn norm2(xs: &[f32]) -> f32 {
    dot(xs, xs).sqrt()
}

/// max |x_i| — the infinity norm that appears in the paper's bounds.
pub fn norm_inf(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
        // length > 4 exercises the unrolled path + remainder
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b = vec![2.0f32; 11];
        assert_eq!(dot(&a, &b), 110.0);
    }

    #[test]
    fn lse_stable() {
        let x = [1000.0f32, 1000.0];
        let l = log_sum_exp(&x);
        assert!((l - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert!(log_sum_exp(&[f32::NEG_INFINITY, 0.0]).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![0.5f32, -1.0, 3.0, 2.0];
        let logz = softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(logz.is_finite());
        assert!(x[2] > x[3] && x[3] > x[0] && x[0] > x[1]);
    }

    #[test]
    fn top_k_orders() {
        let xs = [0.1f32, 5.0, 3.0, 4.0, -1.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 10).len(), 5);
        assert_eq!(top_k(&xs, 0), Vec::<u32>::new());
    }

    #[test]
    fn top_k_ties_deterministic() {
        let xs = [1.0f32; 6];
        assert_eq!(top_k(&xs, 3), vec![0, 1, 2]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm_inf(&[-3.0, 2.0]), 3.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
