//! Small numeric helpers shared across samplers, metrics and stats, plus
//! the runtime SIMD dispatch policy ([`simd_level`]) used by the serving
//! hot path (`dot` here, the u8 ADC kernels in `crate::quant::adc`).

use std::sync::atomic::{AtomicU8, Ordering};

/// Instruction-set tier the SIMD kernels run at, picked once per process
/// by [`simd_level`] (or forced via [`set_simd_level`] / `MIDX_NO_SIMD`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// AVX2: 32-byte integer lanes + 8-float vectors.
    Avx2,
    /// SSSE3: 16-byte lanes (`pshufb` available).
    Ssse3,
    /// Portable scalar fallbacks only.
    Scalar,
}

impl SimdLevel {
    /// Short name for logs (`avx2` / `ssse3` / `scalar`).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Ssse3 => "ssse3",
            SimdLevel::Scalar => "scalar",
        }
    }
}

/// 255 = not yet detected; otherwise the `SimdLevel` discriminant + 1.
static SIMD_LEVEL: AtomicU8 = AtomicU8::new(255);

fn level_code(level: SimdLevel) -> u8 {
    match level {
        SimdLevel::Avx2 => 1,
        SimdLevel::Ssse3 => 2,
        SimdLevel::Scalar => 3,
    }
}

/// Detect the best supported tier, honoring the `MIDX_NO_SIMD` env var
/// (any non-empty value other than `0` forces scalar — the CI fallback
/// leg and `midx --no-simd` use this).
pub fn detect_simd_level() -> SimdLevel {
    if std::env::var("MIDX_NO_SIMD").is_ok_and(|v| !v.is_empty() && v != "0") {
        return SimdLevel::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
        if is_x86_feature_detected!("ssse3") {
            return SimdLevel::Ssse3;
        }
    }
    SimdLevel::Scalar
}

/// The process-wide SIMD tier (detected once, then cached). Every
/// dispatched kernel produces bit-identical results at every tier, so
/// this only ever changes speed, never answers.
pub fn simd_level() -> SimdLevel {
    match SIMD_LEVEL.load(Ordering::Relaxed) {
        1 => SimdLevel::Avx2,
        2 => SimdLevel::Ssse3,
        3 => SimdLevel::Scalar,
        _ => {
            let level = detect_simd_level();
            SIMD_LEVEL.store(level_code(level), Ordering::Relaxed);
            level
        }
    }
}

/// Force the SIMD tier (CLI `--no-simd`, scalar-vs-SIMD equality tests).
/// Forcing a tier the CPU lacks is safe only for `Scalar`; tests restore
/// the detected level afterwards.
pub fn set_simd_level(level: SimdLevel) {
    SIMD_LEVEL.store(level_code(level), Ordering::Relaxed);
}

/// Dot product of two equal-length slices.
///
/// Dispatched over [`simd_level`]: the vector path packs the 4 accumulator
/// lanes of the long-standing 4-way unrolled scalar loop into one SSE
/// register (multiply and add unfused, lanes reduced left to right in the
/// scalar order), so **every tier returns identical bits** — the same
/// bits this crate has produced since the seed. The serve layer's exact
/// re-rank and the golden draw pins both depend on that: answers must not
/// change with the machine the snapshot is served on.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if a.len() >= 16 && simd_level() != SimdLevel::Scalar {
        // SAFETY: SSE2 is baseline on x86_64; both non-scalar tiers imply
        // it. Below 16 elements the call overhead beats the lane win.
        return unsafe { dot_sse2(a, b) };
    }
    dot_scalar(a, b)
}

/// The 4-way unrolled accumulation this crate has always used, kept
/// bit-for-bit: four independent lanes over chunks of 4, lanes summed left
/// to right, then a sequential remainder. The SSE kernel mirrors this
/// exactly. Public so equality tests can pin `dot == dot_scalar` without
/// touching the global dispatch level.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// SSE2 dot kernel: the scalar loop's four accumulator lanes in one
/// register. Separate multiply + add (no FMA) and a lane-order reduction
/// keep every intermediate rounding identical to [`dot_scalar`].
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 4;
    let mut acc = _mm_setzero_ps();
    for i in 0..chunks {
        let va = _mm_loadu_ps(a.as_ptr().add(i * 4));
        let vb = _mm_loadu_ps(b.as_ptr().add(i * 4));
        acc = _mm_add_ps(acc, _mm_mul_ps(va, vb));
    }
    let mut lanes = [0.0f32; 4];
    _mm_storeu_ps(lanes.as_mut_ptr(), acc);
    // left-to-right, exactly like the scalar mirror's s0 + s1 + s2 + s3
    let mut s = lanes[0] + lanes[1] + lanes[2] + lanes[3];
    for j in chunks * 4..n {
        s += *a.get_unchecked(j) * *b.get_unchecked(j);
    }
    s
}

/// Squared euclidean distance.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Numerically stable log-sum-exp.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = xs.iter().map(|&x| ((x - m) as f64).exp()).sum();
    m + (s.ln() as f32)
}

/// In-place softmax; returns the log partition function.
pub fn softmax_inplace(xs: &mut [f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut s = 0.0f64;
    for x in xs.iter_mut() {
        let e = ((*x - m) as f64).exp();
        *x = e as f32;
        s += e;
    }
    let inv = (1.0 / s) as f32;
    for x in xs.iter_mut() {
        *x *= inv;
    }
    m + (s.ln() as f32)
}

/// Indices of the k largest values (descending). O(n log k).
pub fn top_k(xs: &[f32], k: usize) -> Vec<u32> {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Rev(f32, u32);
    impl Eq for Rev {}
    impl PartialOrd for Rev {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Rev {
        fn cmp(&self, o: &Self) -> Ordering {
            // min-heap on value; on ties evict the larger index so the
            // lowest indices win deterministically
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then(self.1.cmp(&o.1))
        }
    }

    let k = k.min(xs.len());
    let mut heap: BinaryHeap<Rev> = BinaryHeap::with_capacity(k + 1);
    for (i, &x) in xs.iter().enumerate() {
        heap.push(Rev(x, i as u32));
        if heap.len() > k {
            heap.pop();
        }
    }
    let mut out: Vec<(f32, u32)> = heap.into_iter().map(|r| (r.0, r.1)).collect();
    out.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    out.into_iter().map(|(_, i)| i).collect()
}

/// argmax with deterministic tie-break (lowest index).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64) as f32
}

/// l2 norm.
pub fn norm2(xs: &[f32]) -> f32 {
    dot(xs, xs).sqrt()
}

/// max |x_i| — the infinity norm that appears in the paper's bounds.
pub fn norm_inf(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
        // length > 8 exercises the unrolled path + remainder
        let a: Vec<f32> = (0..11).map(|i| i as f32).collect();
        let b = vec![2.0f32; 11];
        assert_eq!(dot(&a, &b), 110.0);
    }

    #[test]
    fn dot_simd_is_bit_identical_to_scalar() {
        // awkward magnitudes so any reassociation or FMA contraction would
        // actually change the rounding — lengths straddle the dispatch
        // threshold, the 8-lane chunks and every remainder size
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 33) as f32 / (1u64 << 31) as f32 - 0.5) * 1e3
        };
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 32, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| next()).collect();
            let b: Vec<f32> = (0..n).map(|_| next()).collect();
            assert_eq!(
                dot(&a, &b).to_bits(),
                dot_scalar(&a, &b).to_bits(),
                "dispatched dot diverges from its scalar mirror at n={n} (level {:?})",
                simd_level()
            );
        }
    }

    #[test]
    fn simd_level_detects_and_forces() {
        let detected = simd_level();
        assert!(!detected.name().is_empty());
        set_simd_level(SimdLevel::Scalar);
        assert_eq!(simd_level(), SimdLevel::Scalar);
        // forcing never changes answers, only speed
        let a: Vec<f32> = (0..40).map(|i| (i as f32).sin()).collect();
        let scalar_bits = dot(&a, &a).to_bits();
        set_simd_level(detected);
        assert_eq!(dot(&a, &a).to_bits(), scalar_bits);
    }

    #[test]
    fn lse_stable() {
        let x = [1000.0f32, 1000.0];
        let l = log_sum_exp(&x);
        assert!((l - (1000.0 + 2f32.ln())).abs() < 1e-3);
        assert!(log_sum_exp(&[f32::NEG_INFINITY, 0.0]).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![0.5f32, -1.0, 3.0, 2.0];
        let logz = softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(logz.is_finite());
        assert!(x[2] > x[3] && x[3] > x[0] && x[0] > x[1]);
    }

    #[test]
    fn top_k_orders() {
        let xs = [0.1f32, 5.0, 3.0, 4.0, -1.0];
        assert_eq!(top_k(&xs, 3), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 10).len(), 5);
        assert_eq!(top_k(&xs, 0), Vec::<u32>::new());
    }

    #[test]
    fn top_k_ties_deterministic() {
        let xs = [1.0f32; 6];
        assert_eq!(top_k(&xs, 3), vec![0, 1, 2]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm_inf(&[-3.0, 2.0]), 3.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
