//! Shared infrastructure: RNG, numerics, JSON, bench + property harnesses.

pub mod bench;
pub mod check;
pub mod json;
pub mod math;
pub mod rng;
pub mod storage;

pub use json::Json;
pub use rng::Rng;
pub use storage::Storage;
