//! Deterministic, dependency-free RNG: xoshiro256++ with splitmix64 seeding.
//!
//! Every stochastic component in the library (samplers, data generators,
//! parameter init) threads one of these through explicitly, so experiment
//! runs are bit-reproducible given a seed.

/// xoshiro256++ PRNG (Blackman & Vigna). Fast, high quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal deviate
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded RNG (splitmix64-expanded 256-bit state).
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (e.g. one per worker / per epoch).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Stateless per-item stream: the RNG for item `idx` under `seed`.
    ///
    /// This is the batched sampling engine's reproducibility primitive:
    /// every query in a batch gets `Rng::stream(seed, query_index)`, so the
    /// draw sequence depends only on (seed, index) — never on which thread
    /// processed the query or in what order. The golden-ratio multiply
    /// spreads consecutive indices across the seed space before splitmix64
    /// expands them into full 256-bit states.
    #[inline]
    pub fn stream(seed: u64, idx: u64) -> Rng {
        Rng::new(seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit draw.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) double
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal deviate with the given standard deviation, as f32.
    #[inline]
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Standard Gumbel deviate (for Gumbel-max tricks in tests).
    pub fn gumbel(&mut self) -> f64 {
        let u = self.next_f64().max(f64::MIN_POSITIVE);
        -(-u.ln()).ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights (linear scan).
    /// For repeated sampling from the same weights use `sampler::alias`.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        debug_assert!(total > 0.0);
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_deterministic_and_decorrelated() {
        let mut a = Rng::stream(42, 3);
        let mut b = Rng::stream(42, 3);
        let mut c = Rng::stream(42, 4);
        let mut d = Rng::stream(43, 3);
        for _ in 0..50 {
            let x = a.next_u64();
            assert_eq!(x, b.next_u64());
            assert_ne!(x, c.next_u64());
            assert_ne!(x, d.next_u64());
        }
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
