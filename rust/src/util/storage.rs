//! Cow-like array storage: an owned `Vec<T>` or a zero-copy view into a
//! memory-mapped snapshot file.
//!
//! The serve layer's zero-copy load path (`Snapshot::read_mmap`) borrows
//! f32/u32 payload sections straight out of an `mmap(2)`-ed file instead
//! of copying them into fresh `Vec`s. [`Storage`] is the Cow-like type
//! that threads through `ProductQuantizer` / `ResidualQuantizer`,
//! `InvertedMultiIndex` and the sampler cores so the same structs serve
//! both modes:
//!
//! * **Owned** — a plain `Vec<T>` (training, eager loads). `From<Vec<T>>`
//!   keeps every pre-existing construction site compiling unchanged.
//! * **Mapped** — an (`Arc<MmapRegion>`, byte offset, length) view. Reads
//!   are zero-copy through `Deref<Target = [T]>`; the first mutation
//!   (`DerefMut` / [`Storage::to_mut`]) promotes the section to an owned
//!   copy, copy-on-write style, so incremental index refresh keeps working
//!   against a mapped core at the cost of one copy of the touched section.
//!
//! The mapping itself is raw `mmap(2)` / `munmap(2)` FFI — no new
//! dependencies, the same pattern as the `poll(2)` reactor in
//! `serve::reactor` — and unix-only; on other targets the serve layer
//! falls back to eager loading.

use std::ops::{Deref, DerefMut};
use std::sync::Arc;

use anyhow::{bail, Result};

/// Marker for plain-old-data element types that may be reinterpreted from
/// raw mapped bytes: every bit pattern must be a valid value, and the type
/// must carry no pointers or padding. Sealed — exactly the element types
/// snapshot payload sections contain.
pub trait Pod: Copy + 'static + sealed::Sealed {}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for u32 {}
}

impl Pod for f32 {}
impl Pod for u32 {}

#[cfg(unix)]
mod ffi {
    use std::ffi::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only `mmap(2)` mapping of a whole file, unmapped on drop. All
/// [`Storage`] views into one file share a single region through an `Arc`,
/// so the mapping lives exactly as long as the last section borrowed from
/// it.
pub struct MmapRegion {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

// SAFETY: the region is mapped PROT_READ/MAP_PRIVATE and never written
// through; `munmap` runs only in Drop, which Arc guarantees is unique.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapRegion").field("len", &self.len).finish()
    }
}

impl MmapRegion {
    /// Map `path` read-only in its entirety. Unix-only — callers on other
    /// targets must take the eager path instead.
    #[cfg(unix)]
    pub fn map(path: &std::path::Path) -> Result<MmapRegion> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            bail!("cannot mmap an empty file");
        }
        let len = usize::try_from(len).map_err(|_| anyhow::anyhow!("file too large to map"))?;
        // SAFETY: fd is a freshly opened file, len its exact size; the
        // kernel picks the address. MAP_FAILED (-1) is checked below.
        let ptr = unsafe {
            let (prot, flags) = (ffi::PROT_READ, ffi::MAP_PRIVATE);
            ffi::mmap(std::ptr::null_mut(), len, prot, flags, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 {
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        Ok(MmapRegion { ptr, len })
    }

    /// The mapped file contents.
    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping held until Drop.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        // SAFETY: ptr/len came from a successful mmap and are unmapped once.
        unsafe {
            ffi::munmap(self.ptr, self.len);
        }
    }
}

#[derive(Clone, Debug)]
enum Inner<T> {
    Owned(Vec<T>),
    Mapped { region: Arc<MmapRegion>, byte_off: usize, len: usize },
}

/// Cow-like array storage: owned `Vec<T>` or a borrowed section of a
/// memory-mapped snapshot (see the module docs). Reads go through
/// `Deref<Target = [T]>`; mutation copy-on-writes via [`Storage::to_mut`]
/// (or implicitly through `DerefMut`).
#[derive(Clone, Debug)]
pub struct Storage<T>(Inner<T>);

impl<T: Pod> Storage<T> {
    /// Borrow `len` elements starting `byte_off` bytes into `region`.
    /// Rejects out-of-range and misaligned sections — by construction the
    /// v2 snapshot layout 64-byte-aligns every section, so a rejection
    /// here means the file (or the layout math) is wrong.
    pub(crate) fn mapped(
        region: Arc<MmapRegion>,
        byte_off: usize,
        len: usize,
    ) -> Result<Storage<T>> {
        let size = std::mem::size_of::<T>();
        let bytes = len.checked_mul(size).and_then(|b| b.checked_add(byte_off));
        match bytes {
            Some(end) if end <= region.as_bytes().len() => {}
            _ => bail!(
                "mapped section out of range: {len} elements at byte offset {byte_off} exceed \
                 the {}-byte region",
                region.as_bytes().len()
            ),
        }
        if (region.ptr as usize + byte_off) % std::mem::align_of::<T>() != 0 {
            bail!(
                "mapped section at byte offset {byte_off} is misaligned for {size}-byte elements"
            );
        }
        Ok(Storage(Inner::Mapped { region, byte_off, len }))
    }

    /// True when this storage still borrows from a mapped region (i.e. no
    /// mutation has promoted it to an owned copy).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Inner::Mapped { .. })
    }

    /// The elements as a slice (same as `Deref`, handy where method-call
    /// syntax reads better than reborrowing).
    pub fn as_slice(&self) -> &[T] {
        self
    }

    /// Mutable access, promoting a mapped section to an owned copy first
    /// (copy-on-write). Owned storage mutates in place at no cost.
    pub fn to_mut(&mut self) -> &mut [T] {
        if self.is_mapped() {
            let copy = self.as_slice().to_vec();
            self.0 = Inner::Owned(copy);
        }
        match &mut self.0 {
            Inner::Owned(v) => v,
            Inner::Mapped { .. } => unreachable!("promoted above"),
        }
    }
}

impl<T: Pod> Deref for Storage<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        match &self.0 {
            Inner::Owned(v) => v,
            Inner::Mapped { region, byte_off, len } => {
                // SAFETY: `mapped` bounds- and alignment-checked this view
                // against the region, which the Arc keeps alive; T is Pod,
                // so any mapped bytes are valid values.
                unsafe {
                    std::slice::from_raw_parts(
                        region.as_bytes().as_ptr().add(*byte_off) as *const T,
                        *len,
                    )
                }
            }
        }
    }
}

impl<T: Pod> DerefMut for Storage<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.to_mut()
    }
}

impl<T> From<Vec<T>> for Storage<T> {
    fn from(v: Vec<T>) -> Storage<T> {
        Storage(Inner::Owned(v))
    }
}

impl<T> Default for Storage<T> {
    fn default() -> Storage<T> {
        Storage(Inner::Owned(Vec::new()))
    }
}

impl<T: Pod + PartialEq> PartialEq for Storage<T> {
    fn eq(&self, other: &Storage<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_storage_reads_and_mutates_in_place() {
        let mut s: Storage<u32> = vec![1u32, 2, 3].into();
        assert!(!s.is_mapped());
        assert_eq!(&s[..], &[1, 2, 3]);
        s[1] = 9;
        assert_eq!(s.as_slice(), &[1, 9, 3]);
        assert_eq!(s, Storage::from(vec![1u32, 9, 3]));
        assert_eq!(Storage::<f32>::default().len(), 0);
    }

    #[cfg(unix)]
    fn temp_region(words: &[u32]) -> (std::path::PathBuf, Arc<MmapRegion>) {
        let path = std::env::temp_dir()
            .join(format!("midx_storage_test_{}_{}.bin", std::process::id(), words.len()));
        let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let region = Arc::new(MmapRegion::map(&path).unwrap());
        (path, region)
    }

    #[cfg(unix)]
    #[test]
    fn mapped_storage_is_zero_copy_until_written() {
        let words: Vec<u32> = (0..32u32).collect();
        let (path, region) = temp_region(&words);

        // two disjoint views share one region
        let a: Storage<u32> = Storage::mapped(Arc::clone(&region), 0, 16).unwrap();
        let mut b: Storage<u32> = Storage::mapped(Arc::clone(&region), 64, 16).unwrap();
        assert!(a.is_mapped() && b.is_mapped());
        assert_eq!(&a[..], &words[..16]);
        assert_eq!(&b[..], &words[16..]);

        // CoW: writing promotes b to an owned copy, a stays mapped
        b[0] = 777;
        assert!(!b.is_mapped() && a.is_mapped());
        assert_eq!(b[0], 777);
        assert_eq!(a[0], 0, "sibling view unaffected by the promoted copy");

        // views outlive the file (MAP_PRIVATE) and the path
        std::fs::remove_file(&path).ok();
        drop(region);
        assert_eq!(a[15], 15);
    }

    #[cfg(unix)]
    #[test]
    fn mapped_storage_rejects_out_of_range_and_misaligned_sections() {
        let (path, region) = temp_region(&[1, 2, 3, 4]);
        let err = Storage::<u32>::mapped(Arc::clone(&region), 0, 5).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        let err = Storage::<u32>::mapped(Arc::clone(&region), 2, 2).unwrap_err().to_string();
        assert!(err.contains("misaligned"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[cfg(unix)]
    #[test]
    fn mapping_missing_or_empty_files_fails() {
        assert!(MmapRegion::map(std::path::Path::new("/nonexistent/nope.bin")).is_err());
        let path = std::env::temp_dir()
            .join(format!("midx_storage_test_empty_{}.bin", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        let err = MmapRegion::map(&path).unwrap_err().to_string();
        assert!(err.contains("empty"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
