//! Shared scaffolding for the serving integration harnesses
//! (`serve_load`, `serve_update`, `serve_shard`): one copy of the engine
//! fixture, the reactor lifecycle wrapper, the client plumbing, and —
//! crucially — the deterministic query corpus. The corpus is seeded
//! arithmetic (no RNG state to drift), so every suite and every baseline
//! renders byte-identical request lines for the same (client, request)
//! coordinates.
#![allow(dead_code)]

use std::sync::Arc;

use midx::sampler::fixtures::built_sampler;
use midx::sampler::SamplerKind;
use midx::serve::{QueryEngine, ShardRouter};
use midx::util::Rng;

/// Build a served engine over a fresh synthetic midx-rq snapshot.
pub fn engine(n: usize, d: usize, seed: u64, threads: usize) -> Arc<QueryEngine> {
    let snap = snapshot(n, d, seed);
    Arc::new(QueryEngine::new(snap, threads).unwrap())
}

/// The synthetic midx-rq snapshot behind [`engine`], exposed separately so
/// the shard suite can slice the same snapshot it serves monolithically.
pub fn snapshot(n: usize, d: usize, seed: u64) -> midx::serve::Snapshot {
    snapshot_of(SamplerKind::MidxRq, n, d, seed)
}

/// A synthetic snapshot of any exportable sampler kind over the
/// deterministic [`table`].
pub fn snapshot_of(kind: SamplerKind, n: usize, d: usize, seed: u64) -> midx::serve::Snapshot {
    let table = table(n, d, seed);
    let s = built_sampler(kind, n, d, seed);
    s.snapshot(&table, n, d).unwrap_or_else(|| panic!("{} snapshots", kind.name()))
}

/// A scatter-gather [`ShardRouter`] over the same synthetic snapshot as
/// [`engine`], split evenly into `shards` in-process shards (one worker
/// thread each). The observability suite serves this behind the reactor
/// to pin the sharded `{"op":"metrics"}` round trip (`shards_live` etc.).
pub fn shard_router(n: usize, d: usize, seed: u64, shards: usize) -> Arc<ShardRouter> {
    Arc::new(ShardRouter::split(&snapshot(n, d, seed), shards, 1).unwrap())
}

/// The deterministic embedding table the fixtures are built over.
pub fn table(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    midx::util::check::rand_matrix(&mut rng, n, d, 0.5)
}

/// Deterministic query-vector JSON for (client, request) — load clients,
/// baselines and shard suites all render the exact same text.
pub fn q_json(client: usize, req: usize, d: usize) -> String {
    let vals: Vec<String> =
        (0..d).map(|j| format!("{}", ((client * 31 + req * 7 + j) % 97) as f64 / 97.0)).collect();
    format!("[{}]", vals.join(","))
}

/// The float values behind [`q_json`] (for suites that query the engine
/// directly instead of through the JSON protocol). `q_json`'s text
/// round-trips to exactly these f32s.
pub fn q_vec(client: usize, req: usize, d: usize) -> Vec<f32> {
    (0..d).map(|j| (((client * 31 + req * 7 + j) % 97) as f64 / 97.0) as f32).collect()
}

/// The request line client `c` sends as its `j`-th request (alternating
/// topk / sample, unique seeds per request).
pub fn request_line(c: usize, j: usize, d: usize) -> String {
    let q = q_json(c, j, d);
    if (c + j) % 2 == 0 {
        format!(r#"{{"op":"topk","q":{q},"k":5}}"#)
    } else {
        format!(r#"{{"op":"sample","q":{q},"m":6,"seed":{}}}"#, 10_000 + c * 100 + j)
    }
}

/// Drop the non-deterministic `us` latency field before byte comparison.
pub fn strip_us(s: &str) -> String {
    s.split(",\"us\":").next().unwrap().to_string()
}

// -- reactor plumbing (unix-only, like the reactor itself) -----------------

#[cfg(unix)]
pub use reactor_harness::*;

#[cfg(unix)]
mod reactor_harness {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;
    use std::thread::JoinHandle;
    use std::time::Duration;

    use midx::serve::{LatencyRecorder, MicroBatcher, Reactor, ReactorConfig, ReactorHandle};

    /// A reactor running on an ephemeral port, plus the handles the tests
    /// poke at (batcher stats, reactor counters, graceful shutdown).
    pub struct Served {
        pub addr: SocketAddr,
        pub handle: ReactorHandle,
        pub thread: JoinHandle<anyhow::Result<()>>,
        pub batcher: Arc<MicroBatcher>,
        pub rec: Arc<LatencyRecorder>,
    }

    impl Served {
        /// Graceful drain; panics if the reactor errored.
        pub fn stop(self) {
            self.handle.shutdown();
            self.thread.join().expect("reactor thread").expect("reactor run");
        }
    }

    /// Spin a reactor over `batcher` on an ephemeral port.
    pub fn serve(batcher: Arc<MicroBatcher>, cfg: ReactorConfig) -> Served {
        let rec = Arc::new(LatencyRecorder::new());
        let reactor =
            Reactor::bind("127.0.0.1:0", Arc::clone(&batcher), Arc::clone(&rec), cfg).unwrap();
        let addr = reactor.local_addr().unwrap();
        let handle = reactor.handle();
        let thread = std::thread::spawn(move || reactor.run());
        Served { addr, handle, thread, batcher, rec }
    }

    pub fn connect(addr: SocketAddr) -> TcpStream {
        let s = TcpStream::connect(addr).expect("connect to reactor");
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.set_nodelay(true).ok();
        s
    }

    /// Read exactly `count` reply lines (panics on EOF or timeout — a
    /// stalled or dropped reply is exactly what these harnesses catch).
    pub fn read_replies(reader: &mut BufReader<TcpStream>, count: usize, who: &str) -> Vec<String> {
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            let mut line = String::new();
            let n = reader.read_line(&mut line).unwrap_or_else(|e| {
                panic!("{who}: read of reply {i}/{count} failed: {e}");
            });
            assert!(n > 0, "{who}: connection closed after {i}/{count} replies");
            out.push(line.trim_end().to_string());
        }
        out
    }

    /// One write-half + read-half pair for strictly request/reply traffic.
    pub struct Conn {
        pub w: TcpStream,
        pub r: BufReader<TcpStream>,
    }

    impl Conn {
        pub fn open(addr: SocketAddr) -> Conn {
            let w = connect(addr);
            let r = BufReader::new(w.try_clone().unwrap());
            Conn { w, r }
        }

        /// Send one line, read exactly one reply.
        pub fn send(&mut self, line: &str) -> String {
            self.w.write_all(line.as_bytes()).unwrap();
            self.w.write_all(b"\n").unwrap();
            self.w.flush().unwrap();
            read_replies(&mut self.r, 1, "conn").pop().unwrap()
        }
    }
}
