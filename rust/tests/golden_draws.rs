//! Golden-draw regression: the first 64 draws (16 queries × 4 negatives)
//! per sampler at a fixed seed must be reproduced bit-for-bit by every
//! execution path of the batched engine — the sequential per-query loop,
//! the scoped-thread fallback, and the persistent worker pool — at every
//! thread count in {1, 2, 8} (plus whatever the CI matrix's THREADS env
//! var adds).
//!
//! The draws are additionally pinned against a blessed snapshot file
//! (`golden_draws.snap`, FNV-1a over ids and log-q bit patterns): a change
//! to sampler internals that silently shifts the draw sequence fails here
//! even if all three paths still agree with each other. On first run the
//! snapshot is written; regenerate deliberately with `GOLDEN_BLESS=1`.

use std::fmt::Write as _;

use midx::coordinator::WorkerPool;
use midx::sampler::fixtures::{built_sampler, ALL_KINDS};
use midx::sampler::{sample_batch, sample_batch_pooled, Scratch};
use midx::util::check::rand_matrix;
use midx::util::Rng;

const B: usize = 16;
const M: usize = 4; // B * M = 64 golden draws per sampler
const SEED: u64 = 0x601D;

/// Thread counts under test. The CI matrix's THREADS env var REPLACES the
/// default {1, 2, 8} so each matrix leg does distinct work; locally (no
/// env) all three run in one invocation.
fn thread_counts() -> Vec<usize> {
    if let Ok(v) = std::env::var("THREADS") {
        let ts: Vec<usize> =
            v.split(',').filter_map(|tok| tok.trim().parse().ok()).filter(|&t| t > 0).collect();
        if !ts.is_empty() {
            return ts;
        }
    }
    vec![1, 2, 8]
}

fn fnv1a(h: &mut u64, bytes: &[u8]) {
    for &byte in bytes {
        *h ^= byte as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    }
}

fn assert_bits_equal(tag: &str, ids: &[u32], lq: &[f32], ref_ids: &[u32], ref_lq: &[f32]) {
    assert_eq!(ids, ref_ids, "{tag}: ids diverge from the sequential reference");
    let got: Vec<u32> = lq.iter().map(|x| x.to_bits()).collect();
    let want: Vec<u32> = ref_lq.iter().map(|x| x.to_bits()).collect();
    assert_eq!(got, want, "{tag}: log_q bits diverge from the sequential reference");
}

#[test]
fn golden_draws_reproduce_across_paths_and_thread_counts() {
    let (n, d) = (48usize, 8usize);
    // one pool per thread count, shared across all 8 samplers — also
    // exercises worker reuse across different cores
    let pools: Vec<(usize, WorkerPool)> =
        thread_counts().into_iter().map(|t| (t, WorkerPool::new(t))).collect();

    let mut snapshot = String::new();
    for &kind in ALL_KINDS {
        let s = built_sampler(kind, n, d, 7 + kind as u64);
        let core = s.core();

        let mut qrng = Rng::new(31);
        let queries = rand_matrix(&mut qrng, B, d, 0.5);
        let positives: Vec<u32> = (0..B).map(|i| (i % n) as u32).collect();

        // reference: the sequential per-query path at the same streams
        let mut ref_ids = vec![0u32; B * M];
        let mut ref_lq = vec![0.0f32; B * M];
        let mut scratch = Scratch::new();
        for i in 0..B {
            let mut r = Rng::stream(SEED, i as u64);
            core.sample_into(
                &queries[i * d..(i + 1) * d],
                positives[i],
                &mut r,
                &mut scratch,
                &mut ref_ids[i * M..(i + 1) * M],
                &mut ref_lq[i * M..(i + 1) * M],
            );
        }

        for (t, pool) in &pools {
            // scoped-thread path
            let mut ids = vec![0u32; B * M];
            let mut lq = vec![0.0f32; B * M];
            sample_batch(core, &queries, d, &positives, M, SEED, *t, &mut ids, &mut lq);
            assert_bits_equal(&format!("{} scoped T={t}", core.name()), &ids, &lq, &ref_ids, &ref_lq);

            // persistent-pool path, forced through the workers
            let mut pids = vec![0u32; B * M];
            let mut plq = vec![0.0f32; B * M];
            sample_batch_pooled(
                pool, core, &queries, d, &positives, M, SEED, 0, &mut pids, &mut plq,
            );
            assert_bits_equal(&format!("{} pool T={t}", core.name()), &pids, &plq, &ref_ids, &ref_lq);
        }

        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &id in &ref_ids {
            fnv1a(&mut h, &id.to_le_bytes());
        }
        for &l in &ref_lq {
            fnv1a(&mut h, &l.to_bits().to_le_bytes());
        }
        writeln!(snapshot, "{} {:016x}", core.name(), h).unwrap();
    }

    // The snapshot pin only bites once golden_draws.snap is checked in:
    // this container has no Rust toolchain to generate it, so the first
    // toolchain-bearing run blesses it (loudly) and it should then be
    // committed (ROADMAP). The cross-path/thread-count assertions above
    // hold regardless.
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden_draws.snap");
    let bless = match std::env::var("GOLDEN_BLESS") {
        Ok(v) => !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"),
        Err(_) => false,
    };
    match std::fs::read_to_string(path) {
        Ok(want) if !bless => assert_eq!(
            snapshot, want,
            "golden draw sequences diverged from the blessed snapshot; if the change is \
             an intentional sampler-internals change, regenerate with GOLDEN_BLESS=1"
        ),
        _ => match std::fs::write(path, &snapshot) {
            Ok(()) => eprintln!(
                "golden_draws: blessed new snapshot at {path} — commit this file so \
                 future runs pin against it"
            ),
            // read-only checkout: the cross-path assertions above already
            // passed; losing the pin is not a sampler-correctness failure
            Err(e) => eprintln!("golden_draws: cannot write snapshot at {path}: {e}"),
        },
    }
}
