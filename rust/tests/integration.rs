//! Integration tests over the real AOT artifacts (`make artifacts` first).
//!
//! These exercise the full rust↔PJRT↔HLO ABI: manifest layout, executable
//! signatures, kernel-vs-native parity, and a short end-to-end training run
//! that must reduce the loss.

use std::sync::Arc;

use midx::coordinator::{build_sampler, build_task, ExperimentSpec};
use midx::quant::QuantKind;
use midx::runtime::{lit_f32, lit_i32, load_model, to_f32, to_scalar_f32, Engine};
use midx::sampler::{MidxSampler, Sampler, SamplerKind};
use midx::train::{Batch, TaskData, TrainConfig, Trainer};
use midx::util::math::dot;
use midx::util::Rng;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/index.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn seq_batch(task: &TaskData, seed: u64) -> Batch {
    let mut rng = Rng::new(seed);
    task.train_batch(&mut rng)
}

#[test]
fn encode_artifact_runs_and_is_finite() {
    require_artifacts!();
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("lm_ptb_lstm", Some(SamplerKind::Uniform));
    let sampler = build_sampler(&spec, &manifest, &task);
    let mut trainer = Trainer::new(manifest, sampler, TrainConfig::default()).unwrap();
    let batch = seq_batch(&task, 2);
    let z = trainer.encode_batch(&batch).unwrap();
    assert_eq!(z.len(), trainer.manifest.dims.bq * trainer.manifest.dims.d);
    assert!(z.iter().all(|x| x.is_finite()));
    // different batches produce different embeddings
    let z2 = trainer.encode_batch(&seq_batch(&task, 3)).unwrap();
    assert_ne!(z, z2);
}

#[test]
fn eval_scores_matches_manual_dot_product() {
    require_artifacts!();
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let (n, d, bq) = (manifest.dims.n_classes, manifest.dims.d, manifest.dims.bq);
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("lm_ptb_lstm", Some(SamplerKind::Uniform));
    let sampler = build_sampler(&spec, &manifest, &task);
    let eval_path = manifest.artifact_path("eval_scores").unwrap();
    let mut trainer = Trainer::new(manifest, sampler, TrainConfig::default()).unwrap();
    let batch = seq_batch(&task, 5);
    let z = trainer.encode_batch(&batch).unwrap();

    let engine = trainer.engine();
    let exe = engine.load_hlo(&eval_path).unwrap();
    let mut args = trainer.params.literals().unwrap();
    args.extend(batch.input_literals().unwrap());
    let out = exe.run(&args).unwrap();
    let scores = to_f32(&out[0]).unwrap();
    assert_eq!(scores.len(), bq * n);

    // spot-check a few entries against z·q
    let q = trainer.params.q_table();
    for &(r, c) in &[(0usize, 0usize), (3, 17), (bq - 1, n - 1)] {
        let want = dot(&z[r * d..(r + 1) * d], &q[c * d..(c + 1) * d]);
        let got = scores[r * n + c];
        assert!(
            (want - got).abs() < 1e-3 * (1.0 + want.abs()),
            "score[{r},{c}] {got} vs manual {want}"
        );
    }
}

#[test]
fn training_reduces_loss_all_samplers() {
    require_artifacts!();
    for kind in [None, Some(SamplerKind::Uniform), Some(SamplerKind::MidxRq)] {
        let manifest = load_model("lm_ptb_lstm").unwrap();
        let task = build_task(&manifest, 1).unwrap();
        let spec = ExperimentSpec::new("lm_ptb_lstm", kind);
        let sampler = build_sampler(&spec, &manifest, &task);
        let cfg = TrainConfig {
            epochs: 2,
            steps_per_epoch: 15,
            eval_cap: 2,
            ..TrainConfig::default()
        };
        let label = spec.sampler_label();
        let trainer = Trainer::new(manifest, sampler, cfg).unwrap();
        let res = trainer.run(Arc::new(task)).unwrap();
        assert!(
            res.train_loss[1] < res.train_loss[0],
            "{label}: loss did not decrease: {:?}",
            res.train_loss
        );
        let ppl = res.test.get("ppl").unwrap();
        assert!(ppl.is_finite() && ppl > 1.0, "{label}: bad ppl {ppl}");
    }
}

#[test]
fn training_with_incremental_refresh_reduces_loss_and_books_refreshes() {
    require_artifacts!();
    // --refresh auto end to end: epoch 0 cold-rebuilds (no tracker yet),
    // later epochs refresh incrementally; loss must still go down and the
    // trainer must book the maintenance time in the right buckets.
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("lm_ptb_lstm", Some(SamplerKind::MidxRq));
    let sampler = build_sampler(&spec, &manifest, &task);
    let cfg = TrainConfig {
        epochs: 3,
        steps_per_epoch: 15,
        eval_cap: 2,
        refresh: midx::index::RefreshPolicy::Auto,
        ..TrainConfig::default()
    };
    let trainer = Trainer::new(manifest, sampler, cfg).unwrap();
    let res = trainer.run(Arc::new(task)).unwrap();
    assert!(
        res.train_loss.last().unwrap() < &res.train_loss[0],
        "loss did not decrease: {:?}",
        res.train_loss
    );
    assert!(res.timing.full_rebuilds >= 1, "first epoch must cold-rebuild");
    assert!(
        res.timing.incr_refreshes >= 1,
        "later epochs should refresh incrementally (full={}, incr={})",
        res.timing.full_rebuilds,
        res.timing.incr_refreshes
    );
    assert_eq!(res.timing.full_rebuilds + res.timing.incr_refreshes, 3);
}

#[test]
fn midx_probs_artifact_matches_native_sampler() {
    require_artifacts!();
    // The Pallas joint-proposal kernel and the native rust implementation
    // must agree on the full [K,K] table for PQ quantization.
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let (n, d, bq, k) =
        (manifest.dims.n_classes, manifest.dims.d, manifest.dims.bq, manifest.dims.k_codewords);
    let mut rng = Rng::new(9);
    let table: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.2)).collect();
    let mut sampler = MidxSampler::new(n, QuantKind::Product, k, 10);
    sampler.rebuild(&table, n, d, &mut rng);

    let quant = sampler.quantizer().unwrap();
    let c1 = quant.codebook1().to_vec();
    let c2 = quant.codebook2().to_vec();
    let log_w = sampler.index().unwrap().log_sizes.clone();
    // kernel expects finite log weights; replace -inf with very negative
    let log_w: Vec<f32> =
        log_w.iter().map(|&x| if x.is_finite() { x } else { -1e9 }).collect();

    let zs: Vec<f32> = (0..bq * d).map(|_| rng.normal_f32(0.3)).collect();

    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&manifest.artifact_path("midx_probs").unwrap()).unwrap();
    let args = vec![
        lit_f32(&zs, &[bq, d]).unwrap(),
        lit_f32(&c1, &[k, d / 2]).unwrap(),
        lit_f32(&c2, &[k, d / 2]).unwrap(),
        lit_f32(&log_w, &[k, k]).unwrap(),
    ];
    let out = exe.run(&args).unwrap();
    let kernel_probs = to_f32(&out[0]).unwrap(); // [bq, k, k]

    for r in [0usize, 7, bq - 1] {
        let native = sampler.joint_probs(&zs[r * d..(r + 1) * d]);
        let slice = &kernel_probs[r * k * k..(r + 1) * k * k];
        for b in 0..k * k {
            assert!(
                (native[b] - slice[b]).abs() < 1e-4,
                "row {r} bucket {b}: native {} vs kernel {}",
                native[b],
                slice[b]
            );
        }
    }
}

#[test]
fn full_step_loss_matches_eval_scores_cross_entropy() {
    require_artifacts!();
    // full_step's loss must equal mean(lse(scores) − score[target]) computed
    // from the eval_scores artifact — two independent paths, one number.
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let (n, bq) = (manifest.dims.n_classes, manifest.dims.bq);
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("lm_ptb_lstm", None);
    let sampler = build_sampler(&spec, &manifest, &task);
    let full_path = manifest.artifact_path("full_step").unwrap();
    let eval_path = manifest.artifact_path("eval_scores").unwrap();
    let trainer = Trainer::new(manifest, sampler, TrainConfig::default()).unwrap();
    let batch = seq_batch(&task, 11);

    let engine = trainer.engine();
    let full = engine.load_hlo(&full_path).unwrap();
    let eval = engine.load_hlo(&eval_path).unwrap();

    let mut args = trainer.params.literals().unwrap();
    args.extend(batch.input_literals().unwrap());
    let scores = to_f32(&eval.run(&args).unwrap()[0]).unwrap();

    let mut args = trainer.params.literals().unwrap();
    args.extend(batch.input_literals().unwrap());
    args.push(lit_i32(batch.targets(), &[bq]).unwrap());
    let loss = to_scalar_f32(&full.run(&args).unwrap()[0]).unwrap();

    let mut want = 0.0f64;
    for r in 0..bq {
        let row = &scores[r * n..(r + 1) * n];
        let lse = midx::util::math::log_sum_exp(row);
        want += (lse - row[batch.targets()[r] as usize]) as f64;
    }
    want /= bq as f64;
    assert!(
        (loss as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
        "full_step {loss} vs manual {want}"
    );
}

#[test]
fn codebook_artifact_gradient_descends() {
    require_artifacts!();
    let manifest = load_model("lm_ptb_lstm").unwrap();
    let (n, d, bq, k) =
        (manifest.dims.n_classes, manifest.dims.d, manifest.dims.bq, manifest.dims.k_codewords);
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(&manifest.artifact_path("codebook_rq").unwrap()).unwrap();
    let mut rng = Rng::new(3);
    let q: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.2)).collect();
    let z: Vec<f32> = (0..bq * d).map(|_| rng.normal_f32(0.3)).collect();
    let mut c1: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.2)).collect();
    let mut c2: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.2)).collect();

    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..12 {
        let args = vec![
            lit_f32(&c1, &[k, d]).unwrap(),
            lit_f32(&c2, &[k, d]).unwrap(),
            lit_f32(&q, &[n, d]).unwrap(),
            lit_f32(&z, &[bq, d]).unwrap(),
        ];
        let out = exe.run(&args).unwrap();
        last = to_scalar_f32(&out[0]).unwrap();
        if first.is_none() {
            first = Some(last);
        }
        let g1 = to_f32(&out[3]).unwrap();
        let g2 = to_f32(&out[4]).unwrap();
        for (c, g) in c1.iter_mut().zip(&g1) {
            *c -= 0.05 * g;
        }
        for (c, g) in c2.iter_mut().zip(&g2) {
            *c -= 0.05 * g;
        }
    }
    assert!(last < first.unwrap(), "codebook loss {first:?} -> {last}");
}

#[test]
fn xmc_task_end_to_end() {
    require_artifacts!();
    let manifest = load_model("xmc_amazoncat").unwrap();
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("xmc_amazoncat", Some(SamplerKind::MidxRq));
    let sampler = build_sampler(&spec, &manifest, &task);
    let cfg = TrainConfig { epochs: 1, steps_per_epoch: 8, eval_cap: 2, ..Default::default() };
    let trainer = Trainer::new(manifest, sampler, cfg).unwrap();
    let res = trainer.run(Arc::new(task)).unwrap();
    let p1 = res.test.get("p@1").unwrap();
    assert!((0.0..=1.0).contains(&p1));
}

#[test]
fn training_is_deterministic_given_seed() {
    require_artifacts!();
    let run = || {
        let manifest = load_model("lm_ptb_lstm").unwrap();
        let task = build_task(&manifest, 1).unwrap();
        let spec = ExperimentSpec::new("lm_ptb_lstm", Some(SamplerKind::MidxPq));
        let sampler = build_sampler(&spec, &manifest, &task);
        let cfg = TrainConfig {
            epochs: 1,
            steps_per_epoch: 6,
            eval_cap: 1,
            seed: 777,
            ..TrainConfig::default()
        };
        let trainer = Trainer::new(manifest, sampler, cfg).unwrap();
        trainer.run(Arc::new(task)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.train_loss, b.train_loss, "training not reproducible");
    assert_eq!(
        a.test.get("ppl").unwrap().to_bits(),
        b.test.get("ppl").unwrap().to_bits()
    );
}

#[test]
fn manifest_index_lists_all_and_loads() {
    require_artifacts!();
    let names = midx::runtime::list_models().unwrap();
    assert!(names.len() >= 16, "expected >= 16 configs, got {}", names.len());
    for n in &names {
        let m = load_model(n).unwrap();
        assert!(m.total_params() > 0);
        assert_eq!(m.params.last().unwrap().name, "q_table");
        assert!(m.artifacts.has("encode") && m.artifacts.has("train_step"));
    }
}

#[test]
fn m_sweep_variants_have_expected_shapes() {
    require_artifacts!();
    for (name, m_neg) in [
        ("lm_ptb_lstm_m5", 5usize),
        ("lm_ptb_lstm_m10", 10),
        ("lm_ptb_lstm_m50", 50),
        ("lm_ptb_lstm_m100", 100),
    ] {
        let m = load_model(name).unwrap();
        assert_eq!(m.dims.m_neg, m_neg, "{name}");
    }
}

#[test]
fn rec_task_end_to_end() {
    require_artifacts!();
    let manifest = load_model("rec_ml_gru").unwrap();
    let task = build_task(&manifest, 1).unwrap();
    let spec = ExperimentSpec::new("rec_ml_gru", Some(SamplerKind::MidxPq));
    let sampler = build_sampler(&spec, &manifest, &task);
    let cfg = TrainConfig { epochs: 1, steps_per_epoch: 8, eval_cap: 2, ..Default::default() };
    let trainer = Trainer::new(manifest, sampler, cfg).unwrap();
    let res = trainer.run(Arc::new(task)).unwrap();
    assert!(res.test.get("ndcg@10").is_some());
    assert!(res.test.get("recall@50").is_some());
}
