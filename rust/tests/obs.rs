//! Integration suite for the observability layer (`midx::obs`).
//!
//! Covers the tentpole's contracts end to end: histogram percentiles
//! against a sorted-sample oracle (exact below 32, ≤1/32 relative error
//! above), registry registration and recording under thread contention,
//! span phase partitioning, the slow-query line schema and `MIDX_LOG`
//! filtering through the pure `log::render` core, the `{"op":"metrics"}`
//! round trip through the reactor over both the monolithic engine and a
//! sharded `ShardRouter` backend, and — the hard guarantee — that arming
//! tracing does not change a single answered bit.
//!
//! The metrics registry is process-global and cargo runs the tests in
//! this binary concurrently, so assertions against `Registry::global`
//! check series presence and lower bounds, never absolute counts; tests
//! needing exact numbers build their own `Registry::new()`.

mod common;

use std::sync::Arc;
use std::time::Duration;

use midx::obs::{log, span, Histogram, Registry, Span};
use midx::serve::{handle_line, LatencyRecorder, MicroBatcher};
use midx::util::{Json, Rng};

// -- histogram accuracy ----------------------------------------------------

/// Nearest-rank oracle: the value `percentile(p)` promises to approximate.
fn oracle(sorted: &[u64], p: f64) -> u64 {
    let n = sorted.len();
    let rank = (((p / 100.0) * n as f64).ceil().max(1.0) as usize).min(n);
    sorted[rank - 1]
}

#[test]
fn histogram_percentiles_match_sorted_oracle() {
    // Samples spanning six orders of magnitude, deterministic seed.
    let mut rng = Rng::new(0x0b5_0b5);
    let h = Histogram::new();
    let mut all: Vec<u64> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        // Log-uniform-ish: pick an octave 0..=20, then a value inside it.
        let octave = rng.below(21) as u64;
        let v = (1u64 << octave) + rng.next_u64() % (1u64 << octave);
        h.record(v);
        all.push(v);
    }
    all.sort_unstable();
    assert_eq!(h.count(), 10_000);
    assert_eq!(h.max(), *all.last().unwrap());
    assert_eq!(h.sum(), all.iter().sum::<u64>());

    for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9] {
        let want = oracle(&all, p);
        let got = h.percentile(p);
        if want < 32 {
            assert_eq!(got, want, "p{p}: exact range must be exact");
        } else {
            let err = got.abs_diff(want) as f64 / want as f64;
            assert!(err <= 1.0 / 32.0 + 1e-12, "p{p}: want={want} got={got} err={err}");
        }
    }
    // p100 reports the tracked max exactly, not a bucket midpoint.
    assert_eq!(h.percentile(100.0), *all.last().unwrap());
}

#[test]
fn histogram_is_exact_below_32() {
    let h = Histogram::new();
    let mut all = Vec::new();
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        let v = rng.below(32) as u64;
        h.record(v);
        all.push(v);
    }
    all.sort_unstable();
    for p in [5.0, 50.0, 95.0, 100.0] {
        assert_eq!(h.percentile(p), oracle(&all, p), "p{p}");
    }
}

// -- registry under contention ---------------------------------------------

#[test]
fn registry_survives_eight_thread_contention() {
    let r = Arc::new(Registry::new());
    // Pre-seed the gauge well clear of zero so concurrent sub() calls can
    // never saturate regardless of interleaving.
    r.gauge("open", "gauge under test").add(1_000);
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                // Every thread races the get-or-create path too.
                let c = r.counter("reqs_total", "counter under test");
                let g = r.gauge("open", "gauge under test");
                let h = r.histogram("lat_us", "histogram under test");
                for i in 0..10_000u64 {
                    c.inc();
                    h.record(t as u64 * 10_000 + i);
                }
                g.add(5);
                g.sub(3);
            })
        })
        .collect();
    for th in threads {
        th.join().unwrap();
    }
    assert_eq!(r.counter("reqs_total", "").get(), 80_000);
    assert_eq!(r.gauge("open", "").get(), 1_000 + 8 * 2);
    let h = r.histogram("lat_us", "");
    assert_eq!(h.count(), 80_000);
    assert_eq!(h.max(), 7 * 10_000 + 9_999);
    // Every recorded sample is in some bucket: the percentile walk finds
    // a rank even at the extremes.
    assert!(h.percentile(99.0) >= h.percentile(50.0));
}

// -- span partitioning -----------------------------------------------------

#[test]
fn span_phase_sum_tracks_wall_time() {
    let mut sp = Span::start();
    std::thread::sleep(Duration::from_millis(4));
    sp.mark("parse");
    std::thread::sleep(Duration::from_millis(4));
    sp.mark("execute");
    sp.mark("serialize");
    let sum: u64 = sp.phases().iter().map(|(_, us)| us).sum();
    let total = sp.total_us();
    // Marks partition [start, last-mark]: the sum can only trail the
    // total by the time spent after the final mark.
    assert!(sum <= total, "sum={sum} total={total}");
    assert!(total - sum < 100_000, "unaccounted gap: sum={sum} total={total}");
    assert_eq!(
        sp.phases().iter().map(|(n, _)| *n).collect::<Vec<_>>(),
        vec!["parse", "execute", "serialize"]
    );
}

// -- slow-query schema + log filtering -------------------------------------

// One test fn for everything that mutates the process-wide log level and
// format: cargo runs this binary's tests concurrently and nothing else in
// the suite asserts on rendered log output.
#[test]
fn slow_query_schema_and_level_filtering() {
    log::set_format(log::Format::Json);
    log::set_level(log::Level::Warn);

    // Below the active level: filtered to nothing.
    assert!(log::render(log::Level::Debug, "hidden", &[]).is_none());
    assert!(log::render(log::Level::Info, "hidden", &[]).is_none());

    // The slow-query line: exactly what `--trace-slow-ms` emits, rendered
    // through the same pure core, parses back as one JSON object with the
    // documented fields.
    let mut sp = Span::start();
    sp.mark("parse");
    sp.mark("execute");
    sp.mark("serialize");
    let fields = span::slow_report("sample", &sp, 3, 4, 9);
    let line = log::render(log::Level::Warn, "slow_query", &fields).unwrap();
    let j = Json::parse(&line).expect("slow-query line is valid JSON");
    assert_eq!(j.get("lvl").unwrap().as_str().unwrap(), "warn");
    assert_eq!(j.get("msg").unwrap().as_str().unwrap(), "slow_query");
    assert_eq!(j.get("op").unwrap().as_str().unwrap(), "sample");
    assert_eq!(j.get("shards_live").unwrap().as_usize().unwrap(), 3);
    assert_eq!(j.get("shards").unwrap().as_usize().unwrap(), 4);
    assert_eq!(j.get("generation").unwrap().as_usize().unwrap(), 9);
    assert!(j.get("us").unwrap().as_f64().is_some());
    assert!(j.get("ts").unwrap().as_f64().unwrap() > 0.0);
    let phases = j.get("phases").unwrap().as_obj().unwrap();
    for name in ["parse", "execute", "serialize"] {
        assert!(phases.contains_key(name), "missing phase {name}");
    }

    // Error-only silences warns too.
    log::set_level(log::Level::Error);
    assert!(log::render(log::Level::Warn, "hidden", &fields).is_none());
    assert!(log::render(log::Level::Error, "shown", &[]).is_some());

    // Restore the defaults for the rest of the binary.
    log::set_level(log::Level::Info);
    log::set_format(log::Format::Pretty);
}

// -- metrics op round trips ------------------------------------------------

#[cfg(unix)]
mod round_trip {
    use super::*;
    use midx::serve::ReactorConfig;

    fn metrics_of(reply: &str) -> Json {
        let j = Json::parse(reply).expect("metrics reply parses");
        assert!(matches!(j.get("ok"), Some(Json::Bool(true))), "{reply}");
        j.get("metrics").expect("metrics body").clone()
    }

    fn hist_count(metrics: &Json, name: &str) -> f64 {
        metrics
            .get(name)
            .unwrap_or_else(|| panic!("series {name} missing"))
            .get("count")
            .unwrap_or_else(|| panic!("{name} is not a histogram"))
            .as_f64()
            .unwrap()
    }

    #[test]
    fn metrics_op_over_monolithic_engine() {
        let d = 8;
        let eng = common::engine(60, d, 11, 2);
        let batcher = Arc::new(MicroBatcher::new(eng, Duration::ZERO, 8));
        let served = common::serve(
            Arc::clone(&batcher),
            ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
        );
        let mut conn = common::Conn::open(served.addr);

        // Answer real traffic first so the phase histograms have samples.
        for j in 0..6 {
            let reply = conn.send(&common::request_line(0, j, d));
            assert!(reply.contains("\"ok\":true"), "{reply}");
        }
        let metrics = metrics_of(&conn.send(r#"{"op":"metrics"}"#));

        // Counters and end-to-end latency: at least this connection's six.
        assert!(metrics.get("serve_requests_total").unwrap().as_f64().unwrap() >= 6.0);
        assert!(hist_count(&metrics, "serve_request_us") >= 6.0);
        // Per-phase serve histograms populated by those requests.
        for series in [
            "serve_phase_parse_us",
            "serve_phase_batch_us",
            "serve_phase_scan_us",
            "serve_phase_rerank_us",
            "serve_phase_serialize_us",
        ] {
            assert!(hist_count(&metrics, series) >= 1.0, "{series} never recorded");
        }
        // Reactor mirrors: this connection was accepted.
        assert!(metrics.get("reactor_accepted_total").unwrap().as_f64().unwrap() >= 1.0);
        // Histogram bodies expose the exact-percentile fields.
        let req = metrics.get("serve_request_us").unwrap();
        for k in ["p50", "p95", "p99", "max", "sum"] {
            assert!(req.get(k).unwrap().as_f64().is_some(), "missing {k}");
        }

        drop(conn);
        served.stop();
    }

    #[test]
    fn metrics_op_over_sharded_backend() {
        let d = 8;
        let router = common::shard_router(60, d, 13, 4);
        let batcher = Arc::new(MicroBatcher::new(router, Duration::ZERO, 8));
        let served = common::serve(
            Arc::clone(&batcher),
            ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
        );
        let mut conn = common::Conn::open(served.addr);

        for j in 0..4 {
            let reply = conn.send(&common::request_line(1, j, d));
            assert!(reply.contains("\"ok\":true"), "{reply}");
        }
        let metrics = metrics_of(&conn.send(r#"{"op":"metrics"}"#));

        // The router published its census at construction (this binary
        // builds exactly one, with four live shards)...
        assert_eq!(metrics.get("shards_live").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(metrics.get("shards_total").unwrap().as_f64().unwrap(), 4.0);
        // ...and the scatter/merge phases only the sharded path records.
        assert!(hist_count(&metrics, "serve_phase_scatter_us") >= 1.0);
        assert!(hist_count(&metrics, "serve_phase_merge_us") >= 1.0);

        drop(conn);
        served.stop();
    }
}

// -- the bit-identity pin --------------------------------------------------

/// Arming tracing (slow-query log at threshold 0 = log every request)
/// must not change any answered bit: observability only reads the clock.
#[test]
fn tracing_never_changes_answered_bits() {
    let (n, d) = (120, 8);
    let eng = common::engine(n, d, 17, 2);
    let batcher = MicroBatcher::new(eng, Duration::ZERO, 1);
    let rec = LatencyRecorder::new();

    let corpus: Vec<String> =
        (0..4).flat_map(|c| (0..6).map(move |j| common::request_line(c, j, d))).collect();

    let untraced: Vec<String> =
        corpus.iter().map(|l| common::strip_us(&handle_line(&batcher, &rec, l))).collect();

    // Arm the slow-query log for every request (threshold 0), then replay
    // the identical corpus. Restore the disarmed default before asserting
    // so a failure can't leak the armed state into other tests.
    span::set_slow_threshold_ms(0);
    let traced: Vec<String> =
        corpus.iter().map(|l| common::strip_us(&handle_line(&batcher, &rec, l))).collect();
    span::clear_slow_threshold();

    for (i, (u, t)) in untraced.iter().zip(&traced).enumerate() {
        assert_eq!(u, t, "request {i} answered differently with tracing armed");
    }
}
