//! Incremental index maintenance: the contracts the refresh subsystem must
//! honor, artifact-free (pure library).
//!
//! * tolerance = 0 on an unchanged table is a no-op: draws stay bit-for-bit
//!   identical to the full rebuild the core came from;
//! * PQ reassignment never increases quantization distortion on the new
//!   table (nearest-codeword per subspace is per-item optimal);
//! * after heavy drift, an incremental refresh brings KL(proposal‖softmax)
//!   back below the stale index's KL;
//! * exact MIDX stays EXACT (proposal == softmax of the live table) across
//!   incremental refreshes — the Theorem 1 identity survives maintenance;
//! * the Auto policy cold-rebuilds on first use, refreshes while healthy,
//!   and falls back to a cold rebuild after accumulated churn.

use midx::index::RefreshPolicy;
use midx::quant::{QuantKind, Quantizer};
use midx::sampler::{ExactMidxSampler, MidxSampler, Sampler, UniformSampler};
use midx::stats::divergence::{sampler_kl, softmax_dist};
use midx::util::check::{for_all, rand_matrix};
use midx::util::math::dist2;
use midx::util::Rng;

const INCR0: RefreshPolicy = RefreshPolicy::Incremental { tolerance: 0.0, refine_iters: 0 };

fn draws(
    s: &dyn Sampler,
    d: usize,
    n: usize,
    b: usize,
    m: usize,
    seed: u64,
) -> (Vec<u32>, Vec<u32>) {
    let mut qrng = Rng::new(0xDEC0);
    let queries = rand_matrix(&mut qrng, b, d, 0.7);
    let positives: Vec<u32> = (0..b).map(|i| (i % n) as u32).collect();
    let mut ids = vec![0u32; b * m];
    let mut lq = vec![0.0f32; b * m];
    s.sample_batch(&queries, d, &positives, m, seed, 1, &mut ids, &mut lq);
    (ids, lq.iter().map(|x| x.to_bits()).collect())
}

fn measured_distortion(q: &dyn Quantizer, table: &[f32], n: usize, d: usize) -> f64 {
    let mut rec = vec![0.0f32; d];
    let mut total = 0.0f64;
    for i in 0..n {
        q.reconstruct(i, &mut rec);
        total += dist2(&table[i * d..(i + 1) * d], &rec) as f64;
    }
    total
}

#[test]
fn tolerance_zero_on_unchanged_table_is_draw_identical_to_full_rebuild() {
    // Acceptance gate: incremental refresh must DEGRADE to exact
    // full-rebuild behavior when nothing moved. Two samplers share the
    // same cold rebuild (same k-means RNG); one then takes an incremental
    // refresh over the unchanged table. Their draw streams must be
    // bit-identical, for both quantizer families and with refinement
    // requested (zero drift ⇒ refinement must not run).
    let (n, d, b, m) = (60usize, 8usize, 16usize, 6usize);
    let mut trng = Rng::new(9);
    let table = rand_matrix(&mut trng, n, d, 0.8);
    for kind in [QuantKind::Product, QuantKind::Residual] {
        for refine_iters in [0usize, 3] {
            let mut a = MidxSampler::new(n, kind, 4, 10);
            a.rebuild(&table, n, d, &mut Rng::new(33));

            let policy = RefreshPolicy::Incremental { tolerance: 0.0, refine_iters };
            let mut bs = MidxSampler::new(n, kind, 4, 10);
            // the first rebuild_with cold-rebuilds (no tracker yet) with
            // the SAME k-means rng as `a`'s plain rebuild → identical cores
            let first = bs.rebuild_with(&table, n, d, &mut Rng::new(33), &policy);
            assert!(first.full, "no tracker yet: must cold-rebuild");

            let out = bs.rebuild_with(&table, n, d, &mut Rng::new(77), &policy);
            assert!(!out.full, "tracker present + unchanged table ⇒ incremental");
            assert_eq!(out.drifted, 0, "no row moved");
            assert_eq!(out.reassigned, 0, "no bucket may change");
            assert_eq!(out.scanned, n);

            let want = draws(&a, d, n, b, m, 0xFEED);
            let got = draws(&bs, d, n, b, m, 0xFEED);
            assert_eq!(got.0, want.0, "{kind:?} iters={refine_iters}: ids diverge");
            assert_eq!(got.1, want.1, "{kind:?} iters={refine_iters}: log_q bits diverge");
        }
    }
}

#[test]
fn prop_pq_reassignment_never_increases_distortion_on_drifted_table() {
    // With refine_iters = 0 the codebooks are fixed, and PQ assigns each
    // subspace to its nearest codeword independently — so re-assignment is
    // per-item optimal and total distortion on the NEW table cannot exceed
    // the stale assignment's.
    for_all("PQ reassign distortion ≤ stale", |rng, _| {
        let n = 40 + rng.below(60);
        let d = 6 + 2 * rng.below(3);
        let table0 = rand_matrix(rng, n, d, 0.8);
        let mut table1 = table0.clone();
        for x in table1.iter_mut() {
            *x += rng.normal_f32(0.4);
        }
        let mut s = MidxSampler::new(n, QuantKind::Product, 5, 10);
        // first call under the incremental policy cold-rebuilds AND
        // bootstraps the drift tracker (Full would skip the tracker)
        s.rebuild_with(&table0, n, d, &mut Rng::new(11), &INCR0);
        let stale = measured_distortion(s.quantizer().unwrap(), &table1, n, d);
        let out = s.rebuild_with(&table1, n, d, &mut Rng::new(12), &INCR0);
        if out.full {
            return Err("expected incremental refresh".into());
        }
        let fresh = measured_distortion(s.quantizer().unwrap(), &table1, n, d);
        if fresh <= stale + 1e-3 {
            Ok(())
        } else {
            Err(format!("distortion rose: {fresh} > {stale}"))
        }
    });
}

#[test]
fn prop_incremental_refresh_restores_kl_after_heavy_drift() {
    // The satellite's property: after the table drifts, KL(Q‖P) with an
    // incrementally refreshed index must not exceed the stale index's KL.
    // Drift here is heavy (an independent re-draw), where the stale index
    // carries no information about the new table and the gap is wide.
    for_all("KL(refreshed) ≤ KL(stale)", |rng, case| {
        let n = 60 + rng.below(60);
        let d = 8;
        let kind = if case % 2 == 0 { QuantKind::Product } else { QuantKind::Residual };
        let table0 = rand_matrix(rng, n, d, 0.8);
        let table1 = rand_matrix(rng, n, d, 0.8);
        let queries = rand_matrix(rng, 6, d, 0.8);

        let policy = RefreshPolicy::Incremental { tolerance: 0.0, refine_iters: 2 };
        let mut s = MidxSampler::new(n, kind, 6, 12);
        s.rebuild_with(&table0, n, d, &mut Rng::new(5), &policy); // cold + tracker
        let kl_stale = sampler_kl(&mut s, &queries, &table1, n, d);

        let out = s.rebuild_with(&table1, n, d, &mut Rng::new(7), &policy);
        if out.full {
            return Err("expected incremental refresh".into());
        }
        let kl_fresh = sampler_kl(&mut s, &queries, &table1, n, d);
        if kl_fresh <= kl_stale + 1e-6 {
            Ok(())
        } else {
            Err(format!("KL rose after refresh: {kl_fresh} > {kl_stale}"))
        }
    });
}

#[test]
fn prop_exact_midx_stays_exact_across_incremental_refresh() {
    // Theorem 1 holds for ANY bucket partition as long as the residual
    // stage sees the live table — so the exact sampler must still equal
    // the true softmax after a drift-driven refresh (this pins the
    // core-table re-snapshot).
    for_all("exact MIDX == softmax after refresh", |rng, _| {
        let n = 30 + rng.below(50);
        let d = 4 + rng.below(6);
        let table0 = rand_matrix(rng, n, d, 0.8);
        let mut table1 = table0.clone();
        for x in table1.iter_mut() {
            *x += rng.normal_f32(0.5);
        }
        let z = rand_matrix(rng, 1, d, 0.8);

        let policy = RefreshPolicy::Incremental { tolerance: 0.0, refine_iters: 1 };
        let mut s = ExactMidxSampler::new(n, QuantKind::Product, 3, 8);
        s.rebuild_with(&table0, n, d, &mut Rng::new(17), &policy); // cold + tracker
        let out = s.rebuild_with(&table1, n, d, &mut Rng::new(19), &policy);
        if out.full {
            return Err("expected incremental refresh".into());
        }
        let mut q = vec![0.0f32; n];
        s.proposal_dist(&z, &mut q);
        let p = softmax_dist(&z, &table1, n, d);
        for i in 0..n {
            if (q[i] - p[i]).abs() > 1e-3 * (1.0 + p[i]) {
                return Err(format!("class {i}: {} vs {}", q[i], p[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn auto_policy_rebuilds_cold_then_refreshes_then_falls_back_under_churn() {
    let (n, d) = (80usize, 8usize);
    let mut rng = Rng::new(21);
    let mut table = rand_matrix(&mut rng, n, d, 0.8);
    let mut s = MidxSampler::new(n, QuantKind::Residual, 6, 10);

    // first build: nothing to refresh incrementally
    let o0 = s.rebuild_with(&table, n, d, &mut Rng::new(1), &RefreshPolicy::Auto);
    assert!(o0.full, "first build must be cold");

    // sub-tolerance drift: incremental, and nothing re-assessed
    for x in table.iter_mut() {
        *x += rng.normal_f32(1e-4);
    }
    let o1 = s.rebuild_with(&table, n, d, &mut Rng::new(2), &RefreshPolicy::Auto);
    assert!(!o1.full, "tiny drift must not trigger a cold rebuild");
    assert_eq!(o1.drifted, 0, "movement below the auto tolerance");

    // catastrophic churn: independent tables accumulate bucket moves past
    // the Auto threshold, forcing a cold rebuild within a few epochs
    let mut saw_full = false;
    for epoch in 0u64..4 {
        table = rand_matrix(&mut rng, n, d, 0.8);
        let o = s.rebuild_with(&table, n, d, &mut Rng::new(3 + epoch), &RefreshPolicy::Auto);
        if o.full {
            saw_full = true;
            break;
        }
    }
    assert!(saw_full, "accumulated churn never forced a cold rebuild");
}

#[test]
fn static_samplers_fall_back_to_full_rebuild_for_any_policy() {
    let (n, d) = (20usize, 4usize);
    let mut rng = Rng::new(2);
    let table = rand_matrix(&mut rng, n, d, 1.0);
    let mut s = UniformSampler::new(n);
    for policy in [
        RefreshPolicy::Full,
        RefreshPolicy::Auto,
        RefreshPolicy::Incremental { tolerance: 0.0, refine_iters: 1 },
    ] {
        let out = s.rebuild_with(&table, n, d, &mut Rng::new(3), &policy);
        assert!(out.full, "default rebuild_with must report a full rebuild");
        assert_eq!(out.scanned, n);
    }
}

#[test]
fn full_policy_keeps_no_tracker_so_switching_policies_cold_rebuilds_once() {
    // Under Full the N·D drift snapshot is never allocated (it would never
    // be read); the cost of switching to an incremental policy later is
    // exactly one bootstrap cold rebuild.
    let (n, d) = (40usize, 8usize);
    let mut rng = Rng::new(8);
    let table = rand_matrix(&mut rng, n, d, 0.8);
    let mut s = MidxSampler::new(n, QuantKind::Product, 4, 8);
    assert!(s.rebuild_with(&table, n, d, &mut Rng::new(1), &RefreshPolicy::Full).full);
    assert!(s.rebuild_with(&table, n, d, &mut Rng::new(2), &INCR0).full, "tracker bootstrap");
    assert!(!s.rebuild_with(&table, n, d, &mut Rng::new(3), &INCR0).full);
}

#[test]
fn shape_change_forces_cold_rebuild_under_incremental_policy() {
    let d = 8usize;
    let mut rng = Rng::new(31);
    let table_a = rand_matrix(&mut rng, 50, d, 0.8);
    let table_b = rand_matrix(&mut rng, 70, d, 0.8);
    let mut s = MidxSampler::new(50, QuantKind::Product, 4, 8);
    let policy = RefreshPolicy::Incremental { tolerance: 0.0, refine_iters: 1 };
    assert!(s.rebuild_with(&table_a, 50, d, &mut Rng::new(1), &policy).full);
    // N changed: the tracker no longer matches, must cold-rebuild
    let out = s.rebuild_with(&table_b, 70, d, &mut Rng::new(2), &policy);
    assert!(out.full, "shape change must cold-rebuild");
    assert_eq!(out.scanned, 70);
    // and from there incremental works again
    let out2 = s.rebuild_with(&table_b, 70, d, &mut Rng::new(3), &policy);
    assert!(!out2.full);
}
