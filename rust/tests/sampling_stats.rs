//! Statistical goodness-of-fit for the whole sampler suite: ~100k draws
//! from a fixed small problem, drawn through the production batched engine
//! (persistent worker pool), must match each sampler's own reported
//! proposal distribution under a Pearson χ² test and a KL check.
//!
//! Seeds are fixed, so the test is deterministic — the χ² critical value
//! still uses a far-tail quantile (z = 4.5, α ≈ 3e-6) so only a systematic
//! mismatch between `sample_into` and `proposal_dist` can fail it, never
//! the particular fluctuation a fixed seed happens to land on.

use midx::coordinator::WorkerPool;
use midx::sampler::fixtures::{built_sampler, ALL_KINDS};
use midx::sampler::sample_batch_pooled;
use midx::stats::divergence::{chi_square_critical, chi_square_gof, empirical_kl};
use midx::util::check::rand_matrix;
use midx::util::Rng;

/// Worker count under test: honors the CI matrix's THREADS env var,
/// accepting the same comma-separated list golden_draws does (the first
/// valid entry wins — the pool here is a single fixed size); 0 or unset =
/// available parallelism. Results are bit-identical across counts; the
/// matrix exercises the dispatch paths, not the statistics.
fn pool_threads() -> usize {
    std::env::var("THREADS")
        .ok()
        .and_then(|v| v.split(',').filter_map(|t| t.trim().parse::<usize>().ok()).find(|&t| t > 0))
        .unwrap_or(0)
}

#[test]
fn empirical_distribution_matches_reported_proposal() {
    let (n, d) = (64usize, 8usize);
    let b = 256usize; // rows per engine call (same query in every row)
    let m = 16usize; // draws per row
    let calls = 25usize; // 256 * 16 * 25 = 102_400 draws per sampler
    let pool = WorkerPool::new(pool_threads());

    for &kind in ALL_KINDS {
        let mut s = built_sampler(kind, n, d, 0xC0FFEE ^ kind as u64);
        let mut zrng = Rng::new(0x5EED ^ kind as u64);
        let z = rand_matrix(&mut zrng, 1, d, 0.5);

        // the sampler's own claim about its proposal Q(·|z)
        let mut q = vec![0.0f32; n];
        s.proposal_dist(&z, &mut q);

        // ~100k unconditioned draws through the pooled engine
        let core = s.core();
        let zs: Vec<f32> = (0..b).flat_map(|_| z.iter().copied()).collect();
        let positives = vec![u32::MAX; b];
        let mut ids = vec![0u32; b * m];
        let mut lq = vec![0.0f32; b * m];
        let mut counts = vec![0u64; n];
        for call in 0..calls {
            let seed = 0xD1CE0000u64 ^ ((kind as u64) << 8) ^ call as u64;
            sample_batch_pooled(&pool, core, &zs, d, &positives, m, seed, 0, &mut ids, &mut lq);
            for &id in &ids {
                counts[id as usize] += 1;
            }
        }
        let draws = (b * m * calls) as u64;

        let (stat, df) = chi_square_gof(&counts, &q, draws);
        let crit = chi_square_critical(df, 4.5);
        assert!(
            stat < crit,
            "{}: χ²={stat:.1} ≥ crit={crit:.1} (df={df}) — empirical draws diverge from \
             the sampler's reported proposal",
            core.name()
        );

        // KL(empirical ‖ reported) — the divergence the paper's theory
        // bounds; E[KL] ≈ df/(2·draws) ≈ 3e-4 here, so 0.02 is pure slack
        let emp: Vec<f32> = counts.iter().map(|&c| c as f32 / draws as f32).collect();
        let kl = empirical_kl(&emp, &q);
        assert!(kl < 0.02, "{}: KL(emp‖q) = {kl}", core.name());
    }
}

#[test]
fn fast_scan_u8_proposal_draws_match_its_reported_distribution() {
    // The opt-in u8 ADC fast path (MidxCore::set_fast_scan) draws from a
    // quantized LUT, so it is a *different* proposal than the exact f32
    // one — it gets the same gate as every sampler: ~50k draws through the
    // pooled engine must pass the χ² GOF against the fast path's own
    // reported proposal_dist, and that u8 proposal must stay within KL
    // slack of the exact f32 proposal it approximates.
    use midx::quant::QuantKind;
    use midx::sampler::{MidxSampler, Sampler};

    let (n, d) = (64usize, 8usize);
    let (b, m, calls) = (256usize, 16usize, 13usize); // 256 * 16 * 13 ≈ 53k draws
    let pool = WorkerPool::new(pool_threads());

    for (tag, family) in [("midx-pq", QuantKind::Product), ("midx-rq", QuantKind::Residual)] {
        let mut s = MidxSampler::new(n, family, 4, 8);
        let mut rng = Rng::new(0xFA57 ^ family as u64);
        let table = rand_matrix(&mut rng, n, d, 0.5);
        s.rebuild(&table, n, d, &mut rng);

        let z = rand_matrix(&mut Rng::new(0xACE ^ family as u64), 1, d, 0.5);
        let mut exact_q = vec![0.0f32; n];
        s.proposal_dist(&z, &mut exact_q);
        assert!(s.set_fast_scan(true), "{tag}: fast path refused (K > 256?)");
        let mut q = vec![0.0f32; n];
        s.proposal_dist(&z, &mut q);

        let core = s.core();
        let zs: Vec<f32> = (0..b).flat_map(|_| z.iter().copied()).collect();
        let positives = vec![u32::MAX; b];
        let mut ids = vec![0u32; b * m];
        let mut lq = vec![0.0f32; b * m];
        let mut counts = vec![0u64; n];
        for call in 0..calls {
            let seed = 0xFA570000u64 ^ ((family as u64) << 8) ^ call as u64;
            sample_batch_pooled(&pool, core, &zs, d, &positives, m, seed, 0, &mut ids, &mut lq);
            for &id in &ids {
                counts[id as usize] += 1;
            }
        }
        let draws = (b * m * calls) as u64;

        let (stat, df) = chi_square_gof(&counts, &q, draws);
        let crit = chi_square_critical(df, 4.5);
        assert!(
            stat < crit,
            "{tag} fast-scan: χ²={stat:.1} ≥ crit={crit:.1} (df={df}) — u8-LUT draws \
             diverge from the fast path's reported proposal"
        );

        // the u8 grid only perturbs the proposal slightly: KL against the
        // exact f32 proposal bounds the quantization error end to end
        let kl = empirical_kl(&q, &exact_q);
        assert!(kl < 0.02, "{tag}: KL(u8 ‖ exact) = {kl}");
    }
}

#[test]
fn reported_log_q_is_consistent_with_proposal_dist() {
    // cheap cross-check reused from the conformance family: per-draw log q
    // must be ln q[i] of the reported distribution (the quantity the L1
    // sampled-softmax correction consumes)
    let (n, d, m) = (48usize, 8usize, 24usize);
    for &kind in ALL_KINDS {
        let mut s = built_sampler(kind, n, d, 0xBEEF ^ kind as u64);
        let mut rng = Rng::new(0xFACE ^ kind as u64);
        let z = rand_matrix(&mut rng, 1, d, 0.5);
        let mut q = vec![0.0f32; n];
        s.proposal_dist(&z, &mut q);

        let mut ids = vec![0u32; m];
        let mut lq = vec![0.0f32; m];
        s.sample_into(&z, u32::MAX, &mut rng, &mut ids, &mut lq);
        for j in 0..m {
            let want = (q[ids[j] as usize] as f64).max(1e-30).ln();
            let got = lq[j] as f64;
            assert!(
                (got - want).abs() < 1e-2 * (1.0 + want.abs()),
                "{}: draw {} log_q {got} vs dist {want}",
                s.name(),
                ids[j]
            );
        }
    }
}
