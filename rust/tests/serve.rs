//! Serve-layer integration tests: snapshot round-trips, corruption
//! rejection, loaded-core bit-identity with the source sampler, and top-k
//! agreement with brute force. Artifact-free — everything runs on
//! synthetic tables through the public serve API.
//!
//! The headline contract (ISSUE 4 acceptance): a snapshot exported from a
//! live sampler and reloaded from bytes/disk produces **bit-identical**
//! draws to the in-memory core, for every MIDX variant and for every
//! thread count.

use std::sync::Arc;
use std::time::Duration;

use midx::coordinator::WorkerPool;
use midx::sampler::fixtures::small_params;
use midx::sampler::{build, sample_batch, sample_batch_pooled, Sampler, SamplerKind};
use midx::serve::{LoadMode, MicroBatcher, QueryEngine, Request, Snapshot};
use midx::util::check::rand_matrix;
use midx::util::math::{dot, set_simd_level, simd_level, SimdLevel};
use midx::util::Rng;

const MIDX_KINDS: &[SamplerKind] =
    &[SamplerKind::MidxPq, SamplerKind::MidxRq, SamplerKind::ExactMidx];

/// Build + rebuild a MIDX-family sampler on a deterministic random table.
fn trained(kind: SamplerKind, n: usize, d: usize, seed: u64) -> (Box<dyn Sampler>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let table = rand_matrix(&mut rng, n, d, 0.5);
    let mut s = build(kind, n, &small_params(n));
    s.rebuild(&table, n, d, &mut rng);
    (s, table)
}

/// Unique-ish temp path for file round-trip tests.
fn temp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("midx_serve_test_{}_{tag}.midx", std::process::id()))
}

#[test]
fn loaded_core_draws_bit_identical_at_t1_and_t8() {
    let (n, d, b, m, seed) = (80usize, 8usize, 17usize, 6usize, 0x5EEDu64);
    for &kind in MIDX_KINDS {
        let (s, table) = trained(kind, n, d, 500 + kind as u64);
        let snap = s.snapshot(&table, n, d).expect("MIDX samplers snapshot");

        // through bytes AND through a file: both must reproduce the core
        let from_mem = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        let path = temp_path(snap.kind.name());
        snap.write(&path).unwrap();
        let from_disk = Snapshot::read(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let mut rng = Rng::new(9);
        let queries = rand_matrix(&mut rng, b, d, 0.5);
        let positives: Vec<u32> = (0..b).map(|i| (i % n) as u32).collect();
        let sample = |core: &dyn midx::sampler::SamplerCore, threads: usize| {
            let mut ids = vec![0u32; b * m];
            let mut lq = vec![0.0f32; b * m];
            sample_batch(core, &queries, d, &positives, m, seed, threads, &mut ids, &mut lq);
            let bits: Vec<u32> = lq.iter().map(|x| x.to_bits()).collect();
            (ids, bits)
        };

        let src = s.core();
        for threads in [1usize, 8] {
            let want = sample(src, threads);
            for (label, loaded) in [("bytes", &from_mem), ("disk", &from_disk)] {
                let core = loaded.build_core();
                let got = sample(core.as_ref(), threads);
                assert_eq!(
                    got, want,
                    "{} via {label} at T={threads}: loaded draws diverge",
                    snap.kind.name()
                );
            }
        }

        // and through the pooled path an engine actually serves with
        let pool = WorkerPool::new(3);
        let core = from_mem.build_core();
        let mut ids = vec![0u32; b * m];
        let mut lq = vec![0.0f32; b * m];
        sample_batch_pooled(
            &pool, core.as_ref(), &queries, d, &positives, m, seed, 0, &mut ids, &mut lq,
        );
        let want = sample(src, 1);
        assert_eq!(ids, want.0, "{}: pooled loaded draws diverge", snap.kind.name());
    }
}

#[test]
fn corrupted_and_truncated_files_are_rejected_with_useful_errors() {
    let (s, table) = trained(SamplerKind::MidxRq, 50, 8, 7);
    let snap = s.snapshot(&table, 50, 8).unwrap();
    let good = snap.to_bytes();

    let cases: Vec<(Vec<u8>, &str)> = vec![
        ({ let mut b = good.clone(); b[0] ^= 0xFF; b }, "bad magic"),
        ({ let mut b = good.clone(); b[8] = 99; b }, "version 99 unsupported"),
        (good[..good.len() / 2].to_vec(), "truncated"),
        (good[..40].to_vec(), "smaller than"),
        ({ let mut b = good.clone(); let at = b.len() - 30; b[at] ^= 1; b }, "checksum mismatch"),
    ];
    for (bytes, needle) in cases {
        let path = temp_path(needle.split(' ').next().unwrap());
        std::fs::write(&path, &bytes).unwrap();
        let err = Snapshot::read(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains(needle), "want '{needle}' in: {err}");
        // the path the operator passed must appear in the error chain
        assert!(err.contains("midx_serve_test"), "no file context in: {err}");
    }

    // a missing file also names itself
    let err = Snapshot::read(std::path::Path::new("/nonexistent/nope.midx"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("nope.midx"), "{err}");
}

#[test]
fn top_k_with_full_beam_matches_brute_force_exactly() {
    let (n, d, k) = (70usize, 8usize, 9usize);
    for &kind in MIDX_KINDS {
        let (s, table) = trained(kind, n, d, 900 + kind as u64);
        let snap = s.snapshot(&table, n, d).unwrap();
        // exact-midx snapshots carry the core's own table; score against
        // the table the engine will actually use
        let served = snap.table.clone();
        let mut engine = QueryEngine::new(snap, 2).unwrap();
        engine.set_beam_factor(usize::MAX);

        let mut rng = Rng::new(31);
        let queries = rand_matrix(&mut rng, 5, d, 0.7);
        let (ids, scores) = engine.top_k_batch(&queries, k);
        for (row, query) in queries.chunks(d).enumerate() {
            let mut want: Vec<(f32, u32)> = (0..n)
                .map(|i| (dot(query, &served[i * d..(i + 1) * d]), i as u32))
                .collect();
            want.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
            for j in 0..k {
                assert_eq!(ids[row * k + j], want[j].1, "{kind:?} row {row} rank {j}");
                assert_eq!(
                    scores[row * k + j].to_bits(),
                    want[j].0.to_bits(),
                    "{kind:?} row {row} rank {j}: score"
                );
            }
        }
    }
}

#[test]
fn default_beam_recall_is_high_on_clustered_data() {
    // well-clustered table: members of the same cluster share a bucket, so
    // the stage-score beam finds the right buckets and the exact re-rank
    // must recover most of the true top-k even at the default beam width
    let (n, d, k) = (200usize, 8usize, 10usize);
    let mut rng = Rng::new(5);
    let mut table = vec![0.0f32; n * d];
    for i in 0..n {
        let c = i % 8;
        for j in 0..d {
            let base = if j == c { 2.0 } else { 0.0 };
            table[i * d + j] = base + rng.normal_f32(0.15);
        }
    }
    let mut params = small_params(n);
    params.k_codewords = 8; // one codeword per planted cluster
    let mut s = build(SamplerKind::MidxRq, n, &params);
    s.rebuild(&table, n, d, &mut rng);
    let snap = s.snapshot(&table, n, d).unwrap();
    let engine = QueryEngine::new(snap, 1).unwrap();

    let mut hits = 0usize;
    let mut total = 0usize;
    for case in 0..10 {
        let z = rand_matrix(&mut Rng::new(100 + case), 1, d, 0.7);
        let got: Vec<u32> = engine.top_k(&z, k).into_iter().map(|(c, _)| c).collect();
        let mut want: Vec<(f32, u32)> =
            (0..n).map(|i| (dot(&z, &table[i * d..(i + 1) * d]), i as u32)).collect();
        want.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        let truth: Vec<u32> = want.iter().take(k).map(|&(_, c)| c).collect();
        hits += got.iter().filter(|&&c| truth.contains(&c)).count();
        total += k;
    }
    let recall = hits as f64 / total as f64;
    assert!(recall >= 0.5, "default-beam recall {recall} (chance would be {})", k as f64 / n as f64);
}

#[test]
fn engine_sample_is_bit_identical_to_source_unconditioned_draws() {
    let (n, d, b, m) = (60usize, 8usize, 9usize, 5usize);
    let (s, table) = trained(SamplerKind::MidxPq, n, d, 77);
    let snap = s.snapshot(&table, n, d).unwrap();
    let engine = QueryEngine::new(snap, 3).unwrap();

    let mut rng = Rng::new(13);
    let queries = rand_matrix(&mut rng, b, d, 0.5);
    let (got_ids, got_lq) = engine.sample(&queries, m, 0xFACE);

    let positives = vec![u32::MAX; b];
    let mut want_ids = vec![0u32; b * m];
    let mut want_lq = vec![0.0f32; b * m];
    sample_batch(s.core(), &queries, d, &positives, m, 0xFACE, 1, &mut want_ids, &mut want_lq);
    assert_eq!(got_ids, want_ids);
    assert_eq!(
        got_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        want_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}

#[test]
fn micro_batched_requests_are_independent_of_coalescing() {
    // the same request must get the same answer whether it was served
    // alone (window 0, sequential submits) or coalesced with 15 others
    let (s, table) = trained(SamplerKind::MidxRq, 60, 8, 21);
    let snap = s.snapshot(&table, 60, 8).unwrap();
    let engine = Arc::new(QueryEngine::new(snap, 4).unwrap());

    let mut rng = Rng::new(3);
    let queries: Vec<Vec<f32>> = (0..16).map(|_| rand_matrix(&mut rng, 1, 8, 0.5)).collect();
    let request = |i: usize| {
        if i % 2 == 0 {
            Request::TopK { q: queries[i].clone(), k: 5 }
        } else {
            Request::Sample { q: queries[i].clone(), m: 4, seed: i as u64, fallback: false }
        }
    };

    // alone: no window, submitted one by one
    let solo = MicroBatcher::new(Arc::clone(&engine), Duration::ZERO, 1);
    let alone: Vec<_> = (0..16).map(|i| solo.submit(request(i))).collect();
    drop(solo);

    // coalesced: generous window, concurrent submitters
    let batcher = Arc::new(MicroBatcher::new(engine, Duration::from_millis(2), 64));
    let handles: Vec<_> = (0..16)
        .map(|i| {
            let b = Arc::clone(&batcher);
            let req = request(i);
            std::thread::spawn(move || b.submit(req))
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_eq!(got, alone[i], "request {i} changed under coalescing");
    }
    let (reqs, _) = batcher.stats();
    assert_eq!(reqs, 16);
}

// --------------------------------------------------------------------------
// Static-sampler snapshots (uniform, unigram / alias): round-trip pinned
// like the MIDX family — a loaded core must be draw-for-draw bit-identical
// to the source, through bytes and through disk, at T ∈ {1, 8}.

#[test]
fn static_sampler_snapshots_are_draw_for_draw_bit_identical() {
    let (n, d, b, m, seed) = (90usize, 8usize, 13usize, 7usize, 0xB00Fu64);
    for &kind in &[SamplerKind::Uniform, SamplerKind::Unigram] {
        let mut rng = Rng::new(700 + kind as u64);
        let table = rand_matrix(&mut rng, n, d, 0.5);
        let mut s = build(kind, n, &small_params(n));
        s.rebuild(&table, n, d, &mut rng);
        let snap = s.snapshot(&table, n, d).expect("static samplers snapshot");
        assert_eq!(snap.kind.name(), s.name());

        let from_mem = Snapshot::from_bytes(&snap.to_bytes()).unwrap();
        let path = temp_path(snap.kind.name());
        snap.write(&path).unwrap();
        let from_disk = Snapshot::read(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let queries = rand_matrix(&mut Rng::new(4), b, d, 0.5);
        let positives: Vec<u32> = (0..b).map(|i| (i % n) as u32).collect();
        let sample = |core: &dyn midx::sampler::SamplerCore, threads: usize| {
            let mut ids = vec![0u32; b * m];
            let mut lq = vec![0.0f32; b * m];
            sample_batch(core, &queries, d, &positives, m, seed, threads, &mut ids, &mut lq);
            let bits: Vec<u32> = lq.iter().map(|x| x.to_bits()).collect();
            (ids, bits)
        };

        let src = s.core();
        for threads in [1usize, 8] {
            let want = sample(src, threads);
            for (label, loaded) in [("bytes", &from_mem), ("disk", &from_disk)] {
                let core = loaded.build_core();
                let got = sample(core.as_ref(), threads);
                assert_eq!(
                    got, want,
                    "{} via {label} at T={threads}: loaded static draws diverge",
                    snap.kind.name()
                );
            }
        }
    }
}

// --------------------------------------------------------------------------
// Zero-copy (mmap) snapshot loads: the served answers must be bit-identical
// to an eager load — top-k and draws, at T ∈ {1, 8} — and structural damage
// to the file must be rejected with the operator's path in the error chain.

#[test]
#[cfg(unix)]
fn mmap_loaded_engine_matches_eager_bit_for_bit() {
    let (n, d, b, m, k, seed) = (80usize, 8usize, 11usize, 6usize, 7usize, 0xACEDu64);
    for &kind in MIDX_KINDS {
        let (s, table) = trained(kind, n, d, 1300 + kind as u64);
        let snap = s.snapshot(&table, n, d).unwrap();
        let path = temp_path(&format!("mmap_{}", snap.kind.name()));
        snap.write(&path).unwrap();

        let eager = Snapshot::read_with(&path, LoadMode::Eager).unwrap();
        let mapped = Snapshot::read_with(&path, LoadMode::Mmap).unwrap();
        assert!(mapped.is_mapped(), "{}: mmap load did not borrow", snap.kind.name());
        std::fs::remove_file(&path).ok();

        let queries = rand_matrix(&mut Rng::new(41), b, d, 0.5);
        let positives: Vec<u32> = (0..b).map(|i| (i % n) as u32).collect();
        let draws = |snapshot: Snapshot, threads: usize| {
            let core = snapshot.build_core();
            let mut ids = vec![0u32; b * m];
            let mut lq = vec![0.0f32; b * m];
            sample_batch(
                core.as_ref(), &queries, d, &positives, m, seed, threads, &mut ids, &mut lq,
            );
            let bits: Vec<u32> = lq.iter().map(|x| x.to_bits()).collect();
            (ids, bits)
        };
        for threads in [1usize, 8] {
            let want = draws(eager.clone(), threads);
            let got = draws(mapped.clone(), threads);
            assert_eq!(got, want, "{} T={threads}: mmap draws diverge", snap.kind.name());
        }

        // and through the engine the serving frontend actually uses
        let want_engine = QueryEngine::new(eager, 2).unwrap();
        let got_engine = QueryEngine::new(mapped, 2).unwrap();
        let (want_ids, want_scores) = want_engine.top_k_batch(&queries, k);
        let (got_ids, got_scores) = got_engine.top_k_batch(&queries, k);
        assert_eq!(got_ids, want_ids, "{}: mmap top-k ids diverge", snap.kind.name());
        assert_eq!(
            got_scores.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want_scores.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{}: mmap top-k scores diverge",
            snap.kind.name()
        );
    }
}

#[test]
#[cfg(unix)]
fn mmap_load_rejects_v1_and_damage_with_path_context() {
    let (s, table) = trained(SamplerKind::MidxRq, 50, 8, 7);
    let snap = s.snapshot(&table, 50, 8).unwrap();

    // a v1 (packed, unaligned) snapshot cannot be borrowed zero-copy: the
    // loader must say so, name the file, and the eager path must still work
    let v1 = temp_path("mmap_v1");
    std::fs::write(&v1, snap.to_bytes_with(1)).unwrap();
    let err = format!("{:#}", Snapshot::read_with(&v1, LoadMode::Mmap).unwrap_err());
    assert!(err.contains("predates"), "want version hint in: {err}");
    assert!(err.contains("midx_serve_test"), "no file context in: {err}");
    assert!(err.contains("(mmap)"), "no load-mode context in: {err}");
    Snapshot::read_with(&v1, LoadMode::Eager).expect("v1 stays eager-readable");
    std::fs::remove_file(&v1).ok();

    // truncation inside an array section is caught before any borrow
    let good = snap.to_bytes();
    let cut = temp_path("mmap_cut");
    std::fs::write(&cut, &good[..good.len() / 2]).unwrap();
    let err = format!("{:#}", Snapshot::read_with(&cut, LoadMode::Mmap).unwrap_err());
    assert!(err.contains("truncated"), "want truncation in: {err}");
    assert!(err.contains("midx_serve_test"), "no file context in: {err}");
    std::fs::remove_file(&cut).ok();
}

#[test]
fn top_k_is_bit_identical_with_simd_forced_off() {
    // The fast-scan pipeline quantizes stage scores to u8 for candidate
    // *selection* only; final scores come from exact f32 dots whose SIMD
    // kernel reduces in the same order as the scalar one. So forcing the
    // scalar tier must not move a single bit — ids or scores — on any
    // snapshot kind. (The SIMD level is a process-global; because outputs
    // are tier-independent, flipping it here cannot perturb other tests.)
    let (n, d, b, k) = (90usize, 16usize, 9usize, 8usize);
    let detected = simd_level();
    for &kind in MIDX_KINDS {
        let (s, table) = trained(kind, n, d, 2100 + kind as u64);
        let snap = s.snapshot(&table, n, d).unwrap();
        let engine = QueryEngine::new(snap, 2).unwrap();
        let queries = rand_matrix(&mut Rng::new(77), b, d, 0.7);

        set_simd_level(detected);
        let (fast_ids, fast_scores) = engine.top_k_batch(&queries, k);
        set_simd_level(SimdLevel::Scalar);
        let (slow_ids, slow_scores) = engine.top_k_batch(&queries, k);
        set_simd_level(detected);

        assert_eq!(slow_ids, fast_ids, "{kind:?}: scalar top-k ids diverge from SIMD");
        assert_eq!(
            slow_scores.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fast_scores.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "{kind:?}: scalar top-k scores diverge from SIMD"
        );
    }
}

#[test]
fn fallback_snapshot_served_draws_match_the_static_core() {
    // a MIDX primary with a unigram fallback: fallback-flagged sample
    // requests must reproduce the static core's draws exactly, and must
    // not perturb the primary's
    let (n, d, m) = (60usize, 8usize, 6usize);
    let (s, table) = trained(SamplerKind::MidxRq, n, d, 33);
    let snap = s.snapshot(&table, n, d).unwrap();
    let mut engine = QueryEngine::new(snap, 2).unwrap();

    let mut static_s = build(SamplerKind::Unigram, n, &small_params(n));
    let mut rng = Rng::new(5);
    static_s.rebuild(&table, n, d, &mut rng);
    let fb_snap = static_s.snapshot(&table, n, d).unwrap();
    engine.attach_fallback(Snapshot::from_bytes(&fb_snap.to_bytes()).unwrap()).unwrap();

    let queries = rand_matrix(&mut Rng::new(6), 9, d, 0.5);
    let (fb_ids, fb_lq) = engine.sample_fallback(&queries, m, 0xFEED).unwrap();

    let positives = vec![u32::MAX; 9];
    let mut want_ids = vec![0u32; 9 * m];
    let mut want_lq = vec![0.0f32; 9 * m];
    sample_batch(
        static_s.core(), &queries, d, &positives, m, 0xFEED, 1, &mut want_ids, &mut want_lq,
    );
    assert_eq!(fb_ids, want_ids, "fallback draws diverge from the static core");
    assert_eq!(
        fb_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        want_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );

    // primary unaffected: same answers as an engine without a fallback
    let (s2, table2) = trained(SamplerKind::MidxRq, n, d, 33);
    let plain = QueryEngine::new(s2.snapshot(&table2, n, d).unwrap(), 2).unwrap();
    let (a_ids, a_lq) = engine.sample(&queries, m, 0xFEED);
    let (b_ids, b_lq) = plain.sample(&queries, m, 0xFEED);
    assert_eq!(a_ids, b_ids);
    assert_eq!(
        a_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        b_lq.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
    );
}
