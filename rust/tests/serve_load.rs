//! Deterministic load/soak harness for the event-driven serving frontend
//! (`serve::reactor`).
//!
//! What it proves (ISSUE 5 acceptance):
//!
//! * **Exactly-once, in-order, bit-identical**: N client threads × M
//!   pipelined requests over multiplexed connections each get exactly one
//!   reply, in request order, byte-identical (modulo the `us` latency
//!   field) to the same line answered by the blocking single-connection
//!   baseline (`serve::server::handle_line`).
//! * **Deterministic backpressure**: with the batcher paused and the
//!   admission queue capped at C, a pipeline of C+X requests yields
//!   exactly C real replies and exactly X `busy` replies — the busy path
//!   fires iff the cap is exceeded, never sooner, never later.
//! * **Statistics survive the reactor**: draws from the served `sample`
//!   op collected over multiplexed connections pass a Pearson χ²
//!   goodness-of-fit test against the core's own proposal distribution —
//!   coalescing + the event loop do not perturb sampling.
//! * **Hostile input is contained**: oversized lines, frames split across
//!   arbitrary writes, interleaved garbage, and abrupt mid-request
//!   disconnects never panic the server or stall other connections.
//! * **Graceful drain**: shutdown answers everything in flight, flushes,
//!   then closes; idle connections are reaped on their timeout.
//!
//! The reactor is unix-only (raw `poll(2)`), so this whole suite is too.
#![cfg(unix)]

use std::io::{BufRead, BufReader, Write};
use std::sync::Arc;
use std::time::{Duration, Instant};

use midx::sampler::Scratch;
use midx::serve::{handle_line, LatencyRecorder, MicroBatcher, ReactorConfig};
use midx::stats::divergence::{chi_square_critical, chi_square_gof};
use midx::util::{Json, Rng};

mod common;
use common::{connect, engine, q_json, read_replies, request_line, serve, strip_us};

// -- the load harness ------------------------------------------------------

#[test]
fn sixty_four_multiplexed_connections_answer_exactly_once_and_identically() {
    const CLIENTS: usize = 64;
    const REQS: usize = 20;
    let (n, d) = (60usize, 8usize);
    let eng = engine(n, d, 0x10AD, 2);
    let batcher = Arc::new(MicroBatcher::with_queue_cap(
        Arc::clone(&eng),
        Duration::from_micros(200),
        64,
        4096,
    ));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig {
            max_conns: CLIENTS + 8,
            idle_timeout: Duration::ZERO,
            ..Default::default()
        },
    );

    // single-connection baseline through the blocking frontend, on its own
    // batcher over the very same engine
    let solo = MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 1);
    let solo_rec = LatencyRecorder::new();
    let mut baseline: Vec<Vec<String>> = Vec::with_capacity(CLIENTS);
    for c in 0..CLIENTS {
        baseline.push(
            (0..REQS)
                .map(|j| strip_us(&handle_line(&solo, &solo_rec, &request_line(c, j, d))))
                .collect(),
        );
    }

    let addr = served.addr;
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut stream = connect(addr);
                // pipeline all M requests in one burst
                let burst: String =
                    (0..REQS).map(|j| request_line(c, j, d) + "\n").collect();
                stream.write_all(burst.as_bytes()).unwrap();
                stream.flush().unwrap();
                let mut reader = BufReader::new(stream);
                read_replies(&mut reader, REQS, &format!("client {c}"))
            })
        })
        .collect();

    for (c, h) in clients.into_iter().enumerate() {
        let replies = h.join().expect("client thread");
        assert_eq!(replies.len(), REQS, "client {c}: exactly one reply per request");
        for (j, reply) in replies.iter().enumerate() {
            assert!(reply.contains(r#""ok":true"#), "client {c} req {j}: {reply}");
            assert_eq!(
                strip_us(reply),
                baseline[c][j],
                "client {c} req {j}: multiplexed reply diverges from the single-connection \
                 baseline"
            );
        }
    }

    // exactly-once at the server, too: every request admitted and recorded
    // exactly once, nothing refused at this cap
    let (accepted, dispatches) = served.batcher.stats();
    assert_eq!(accepted, (CLIENTS * REQS) as u64, "admitted request count");
    assert!(dispatches >= 1 && dispatches <= accepted, "dispatches {dispatches}");
    assert_eq!(served.batcher.rejected(), 0);
    assert_eq!(served.rec.count(), CLIENTS * REQS, "latency ledger count");
    let counters = served.handle.counters();
    assert_eq!(counters.accepted, CLIENTS as u64);
    assert_eq!(counters.busy, 0);
    served.stop();
}

#[test]
fn busy_fires_exactly_when_the_admission_queue_cap_is_exceeded() {
    const CAP: usize = 8;
    const TOTAL: usize = 20;
    let (n, d) = (50usize, 6usize);
    let eng = engine(n, d, 0xB551, 1);
    let batcher =
        Arc::new(MicroBatcher::with_queue_cap(Arc::clone(&eng), Duration::ZERO, 64, CAP));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
    );

    // freeze the dispatcher: admissions queue up deterministically
    batcher.pause();
    let mut stream = connect(served.addr);
    let burst: String = (0..TOTAL)
        .map(|j| format!(r#"{{"op":"sample","q":{},"m":3,"seed":{j}}}"#, q_json(0, j, d)) + "\n")
        .collect();
    stream.write_all(burst.as_bytes()).unwrap();
    stream.flush().unwrap();

    // wait until the reactor has classified every request (busy counter is
    // the last thing it bumps), then unfreeze
    let deadline = Instant::now() + Duration::from_secs(10);
    while served.handle.counters().busy < (TOTAL - CAP) as u64 {
        assert!(Instant::now() < deadline, "reactor never refused the overflow");
        std::thread::sleep(Duration::from_millis(2));
    }
    batcher.resume();

    let mut reader = BufReader::new(stream);
    let replies = read_replies(&mut reader, TOTAL, "busy client");
    for (j, reply) in replies.iter().enumerate() {
        if j < CAP {
            assert!(
                reply.contains(r#""ok":true"#),
                "request {j} was under the cap and must be served: {reply}"
            );
        } else {
            assert!(
                reply.contains(r#""busy":true"#),
                "request {j} exceeded the cap and must be refused: {reply}"
            );
        }
    }
    assert_eq!(served.batcher.rejected(), (TOTAL - CAP) as u64);
    assert_eq!(served.handle.counters().busy, (TOTAL - CAP) as u64);

    // the cap is about queue depth, not history: once drained, the same
    // connection serves again with zero additional busy replies
    let mut stream2 = reader.into_inner();
    let retry: String = (0..CAP).map(|j| request_line(1, j, d) + "\n").collect();
    stream2.write_all(retry.as_bytes()).unwrap();
    let mut reader2 = BufReader::new(stream2);
    for reply in read_replies(&mut reader2, CAP, "retry client") {
        assert!(reply.contains(r#""ok":true"#), "{reply}");
    }
    assert_eq!(served.handle.counters().busy, (TOTAL - CAP) as u64, "no new busy replies");
    served.stop();
}

#[test]
fn served_sample_statistics_survive_multiplexing() {
    const CLIENTS: usize = 4;
    const REQS: usize = 30;
    const M: usize = 500; // 4 × 30 × 500 = 60k draws
    let (n, d) = (48usize, 8usize);
    let eng = engine(n, d, 0xC417, 2);

    // one fixed query; its JSON text round-trips to the exact f32s below
    let z: Vec<f32> = {
        let mut rng = Rng::new(0x21);
        midx::util::check::rand_matrix(&mut rng, 1, d, 0.5)
    };
    let z_json =
        format!("[{}]", z.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(","));

    // the core's own claim about Q(·|z)
    let mut q = vec![0.0f32; n];
    eng.core().proposal_dist(&z, &mut Scratch::new(), &mut q);

    let batcher = Arc::new(MicroBatcher::with_queue_cap(
        Arc::clone(&eng),
        Duration::from_micros(200),
        64,
        4096,
    ));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
    );

    let addr = served.addr;
    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let z_json = z_json.clone();
            std::thread::spawn(move || {
                let mut stream = connect(addr);
                let burst: String = (0..REQS)
                    .map(|j| {
                        format!(
                            r#"{{"op":"sample","q":{z_json},"m":{M},"seed":{}}}"#,
                            77_000 + c * 1000 + j
                        ) + "\n"
                    })
                    .collect();
                stream.write_all(burst.as_bytes()).unwrap();
                let mut reader = BufReader::new(stream);
                let mut counts = vec![0u64; n];
                for reply in read_replies(&mut reader, REQS, &format!("χ² client {c}")) {
                    let j = Json::parse(&reply).expect("reply is JSON");
                    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{reply}");
                    for id in j.get("ids").and_then(|v| v.as_arr()).expect("ids array") {
                        counts[id.as_usize().unwrap()] += 1;
                    }
                }
                counts
            })
        })
        .collect();

    let mut counts = vec![0u64; n];
    for h in workers {
        for (i, c) in h.join().expect("χ² client").into_iter().enumerate() {
            counts[i] += c;
        }
    }
    let draws = (CLIENTS * REQS * M) as u64;
    assert_eq!(counts.iter().sum::<u64>(), draws, "every draw accounted for");

    let (stat, df) = chi_square_gof(&counts, &q, draws);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): draws served through the reactor diverge \
         from the core's proposal distribution"
    );
    served.stop();
}

#[test]
fn hostile_input_is_contained_to_its_connection() {
    let (n, d) = (50usize, 6usize);
    let eng = engine(n, d, 0xBAD, 1);
    let batcher = Arc::new(MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 16));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig {
            max_line: 1024,
            idle_timeout: Duration::ZERO,
            ..Default::default()
        },
    );

    // a well-behaved bystander connection, kept open throughout
    let mut bystander = connect(served.addr);
    bystander.write_all((request_line(9, 0, d) + "\n").as_bytes()).unwrap();
    let mut bystander_rd = BufReader::new(bystander.try_clone().unwrap());
    let r = read_replies(&mut bystander_rd, 1, "bystander");
    assert!(r[0].contains(r#""ok":true"#));

    // (1) oversized line: one descriptive error, then the connection closes
    {
        let mut s = connect(served.addr);
        s.write_all(&vec![b'x'; 4096]).unwrap();
        s.write_all(b"\n").unwrap();
        let mut rd = BufReader::new(s);
        let r = read_replies(&mut rd, 1, "oversize");
        assert!(r[0].contains("frame limit"), "{}", r[0]);
        let mut end = String::new();
        assert_eq!(rd.read_line(&mut end).unwrap(), 0, "oversized conn must close");
    }

    // (2) a frame split across many tiny writes still parses
    {
        let mut s = connect(served.addr);
        let line = request_line(5, 1, d) + "\n";
        for chunk in line.as_bytes().chunks(3) {
            s.write_all(chunk).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut rd = BufReader::new(s);
        let r = read_replies(&mut rd, 1, "split-frame");
        assert!(r[0].contains(r#""ok":true"#), "{}", r[0]);
    }

    // (3) garbage interleaved between valid requests: error replies in
    // order, valid requests unharmed, connection stays up
    {
        let mut s = connect(served.addr);
        let burst = format!(
            "not json at all\n{}\n\n\x07\x03garbage\u{1}bytes\n{}\n",
            request_line(6, 0, d),
            request_line(6, 1, d)
        );
        s.write_all(burst.as_bytes()).unwrap();
        let mut rd = BufReader::new(s);
        let r = read_replies(&mut rd, 4, "garbage-interleaved");
        assert!(r[0].contains(r#""ok":false"#) && r[0].contains("bad JSON"), "{}", r[0]);
        assert!(r[1].contains(r#""ok":true"#), "{}", r[1]);
        assert!(r[2].contains(r#""ok":false"#), "{}", r[2]);
        assert!(r[3].contains(r#""ok":true"#), "{}", r[3]);
    }

    // (4) abrupt disconnect mid-request: no reply owed, nothing leaks
    {
        let mut s = connect(served.addr);
        s.write_all(br#"{"op":"topk","q":[0.1,"#).unwrap();
        s.flush().unwrap();
        drop(s); // vanish mid-frame
    }

    // the bystander (and the server) survived all of it
    bystander.write_all((request_line(9, 1, d) + "\n").as_bytes()).unwrap();
    let r = read_replies(&mut bystander_rd, 1, "bystander after chaos");
    assert!(r[0].contains(r#""ok":true"#), "{}", r[0]);
    served.stop();
}

#[test]
fn graceful_drain_answers_in_flight_requests_then_closes() {
    const CLIENTS: usize = 2;
    const REQS: usize = 5;
    let (n, d) = (50usize, 6usize);
    let eng = engine(n, d, 0xD7A1, 2);
    let batcher = Arc::new(MicroBatcher::new(Arc::clone(&eng), Duration::from_micros(100), 32));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
    );

    let mut streams = Vec::new();
    for c in 0..CLIENTS {
        let mut s = connect(served.addr);
        let burst: String = (0..REQS).map(|j| request_line(c, j, d) + "\n").collect();
        s.write_all(burst.as_bytes()).unwrap();
        streams.push(s);
    }

    // all requests ingested → drain
    let deadline = Instant::now() + Duration::from_secs(10);
    while served.batcher.stats().0 < (CLIENTS * REQS) as u64 {
        assert!(Instant::now() < deadline, "requests never ingested");
        std::thread::sleep(Duration::from_millis(2));
    }
    served.handle.shutdown();

    for (c, s) in streams.into_iter().enumerate() {
        let mut rd = BufReader::new(s);
        let replies = read_replies(&mut rd, REQS, &format!("drain client {c}"));
        for (j, r) in replies.iter().enumerate() {
            assert!(r.contains(r#""ok":true"#), "client {c} req {j}: {r}");
        }
        // after the drain: EOF, not a hang
        let mut end = String::new();
        assert_eq!(rd.read_line(&mut end).unwrap(), 0, "client {c}: drained conn must close");
    }
    served.thread.join().expect("reactor thread").expect("reactor run");
}

#[test]
fn idle_connections_are_reaped_and_stats_report_reactor_counters() {
    let (n, d) = (50usize, 6usize);
    let eng = engine(n, d, 0x1D1E, 1);
    let batcher = Arc::new(MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 16));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig {
            idle_timeout: Duration::from_millis(200),
            ..Default::default()
        },
    );

    let mut s = connect(served.addr);
    s.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut rd = BufReader::new(s.try_clone().unwrap());
    let r = read_replies(&mut rd, 1, "stats");
    assert!(r[0].contains(r#""conns":1"#), "{}", r[0]);
    assert!(r[0].contains(r#""busy":0"#), "{}", r[0]);

    // now go quiet: the reactor must reap us on the idle timeout
    let mut end = String::new();
    let n_read = rd.read_line(&mut end).unwrap();
    assert_eq!(n_read, 0, "idle connection must be closed by the server");
    let deadline = Instant::now() + Duration::from_secs(5);
    while served.handle.counters().idle_closed < 1 {
        assert!(Instant::now() < deadline, "idle close not counted");
        std::thread::sleep(Duration::from_millis(5));
    }
    served.stop();
}
