//! Multi-process remote serving harness: real `midx serve --shard-id`
//! child processes (the compiled binary, over an `export --shards`
//! manifest on disk) behind an in-process [`RemoteRouter`].
//!
//! This is the network analogue of `serve_shard.rs`, and it pins the same
//! contracts end-to-end through actual sockets and process boundaries:
//!
//! * merged top-k **bit-identical** to the monolithic engine at full beam
//!   (scores cross the wire as shortest-round-trip JSON numbers, so not a
//!   single bit may move);
//! * merged draws **distribution-identical** — a χ² GOF against the exact
//!   softmax over exact-midx shards;
//! * a killed shard process degrades answers to `partial:true` within the
//!   scatter deadline instead of hanging or failing the query;
//! * a live-update push that has reached only part of the fleet makes
//!   merges refuse (mixed generations) until every shard has applied it.
//!
//! Unix-only, like the router itself (both ride the `poll(2)` loop).
#![cfg(unix)]

mod common;

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use common::{q_vec, snapshot, snapshot_of};
use midx::sampler::SamplerKind;
use midx::serve::{export_shards, Backend, QueryEngine, RemoteConfig, RemoteRouter, Request};
use midx::stats::divergence::{chi_square_critical, chi_square_gof, softmax_dist};

/// A running `midx serve --shard-id` child; killed on drop so a failing
/// assertion never leaks server processes.
struct ShardProc {
    child: Child,
    addr: String,
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A per-test scratch directory for the exported shard fleet.
fn fleet_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("midx-serve-remote-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Spawn one shard process on an ephemeral port and wait for its
/// "serving on ADDR" banner. Stderr keeps draining on a side thread so
/// the child can never block on a full pipe.
fn spawn_shard(manifest: &Path, id: usize) -> ShardProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_midx"))
        .args([
            "serve",
            "--snapshot",
            manifest.to_str().unwrap(),
            "--shard-id",
            &id.to_string(),
            "--tcp",
            "127.0.0.1:0",
            "--beam",
            "1000000",
            "--threads",
            "1",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning midx serve shard");
    let mut reader = BufReader::new(child.stderr.take().unwrap());
    let mut seen = String::new();
    let addr = loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).expect("reading shard stderr");
        assert!(n > 0, "shard {id} exited before announcing an address; stderr:\n{seen}");
        seen.push_str(&line);
        if let Some(rest) = line.split("serving on ").nth(1) {
            break rest.split_whitespace().next().expect("address token").to_string();
        }
    };
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
            sink.clear();
        }
    });
    ShardProc { child, addr }
}

/// Export `snap` as an S-shard fleet under a scratch dir, spawn one child
/// process per shard, and return the running fleet + manifest path.
fn spawn_fleet(
    snap: &midx::serve::Snapshot,
    shards: usize,
    tag: &str,
) -> (Vec<ShardProc>, PathBuf) {
    let dir = fleet_dir(tag);
    let manifest = dir.join("fleet.midx");
    export_shards(snap, shards, &manifest).expect("exporting shard fleet");
    let procs = (0..shards).map(|i| spawn_shard(&manifest, i)).collect();
    (procs, manifest)
}

fn router(procs: &[ShardProc], deadline: Duration) -> RemoteRouter {
    let addrs: Vec<String> = procs.iter().map(|p| p.addr.clone()).collect();
    RemoteRouter::connect(
        &addrs,
        RemoteConfig {
            deadline,
            // long probe cadence: tests drive failure + recovery explicitly
            probe_interval: Duration::from_secs(60),
            connect_timeout: Duration::from_secs(10),
        },
    )
    .expect("connecting remote router")
}

fn bits(scores: &[f32]) -> Vec<u32> {
    scores.iter().map(|s| s.to_bits()).collect()
}

// -- exactness -------------------------------------------------------------

#[test]
fn merged_topk_is_bit_identical_to_the_monolithic_engine() {
    let (n, d, k) = (400usize, 8usize, 10usize);
    let snap = snapshot(n, d, 0xBEEF);
    let (procs, _manifest) = spawn_fleet(&snap, 3, "topk");
    let remote = router(&procs, Duration::from_secs(30));
    assert_eq!(remote.n_classes(), n);
    assert_eq!(remote.dim(), d);
    assert_eq!(remote.shard_info(), (3, 3));

    let mut mono = QueryEngine::new(snap, 1).unwrap();
    mono.set_beam_factor(usize::MAX);

    let reqs: Vec<Request> =
        (0..12).map(|c| Request::TopK { q: q_vec(c, 0, d), k }).collect();
    let replies = remote.run_requests(&reqs);
    for (c, rep) in replies.iter().enumerate() {
        assert!(rep.error.is_none(), "query {c}: {:?}", rep.error);
        assert!(!rep.partial, "query {c}: healthy fleet answered partial");
        let want = mono.top_k(&q_vec(c, 0, d), k);
        let want_ids: Vec<u32> = want.iter().map(|&(id, _)| id).collect();
        let want_scores: Vec<f32> = want.iter().map(|&(_, s)| s).collect();
        assert_eq!(rep.ids, want_ids, "query {c}: merged ids diverge");
        assert_eq!(
            bits(&rep.scores),
            bits(&want_scores),
            "query {c}: merged scores are not bit-identical"
        );
    }
}

// -- distribution ----------------------------------------------------------

#[test]
fn merged_draws_pass_chi_square_against_the_exact_softmax() {
    // exact-midx shards: each shard's proposal IS its softmax slice and
    // the masses compose exactly, so merged remote draws must be
    // indistinguishable from softmax(z·Qᵀ) — even though the draw streams
    // themselves differ from the in-process router (wire seeds are capped
    // at 2^53).
    let (n, d) = (48usize, 8usize);
    let snap = snapshot_of(SamplerKind::ExactMidx, n, d, 0xE5A7);
    let z = q_vec(7, 1, d);
    let probs = softmax_dist(&z, &snap.table, n, d);
    let (procs, _manifest) = spawn_fleet(&snap, 3, "chi2");
    let remote = router(&procs, Duration::from_secs(30));

    // two pooled requests keep every per-shard quota far under the wire's
    // 2^16 draws-per-request cap even if the mass skews to one shard
    const PER_REQ: usize = 48_000;
    let reqs = vec![
        Request::Sample { q: z.clone(), m: PER_REQ, seed: 0xFEED, fallback: false },
        Request::Sample { q: z.clone(), m: PER_REQ, seed: 0xF00D, fallback: false },
    ];
    let replies = remote.run_requests(&reqs);
    let mut counts = vec![0u64; n];
    let mut checked = 0usize;
    for rep in &replies {
        assert!(rep.error.is_none(), "{:?}", rep.error);
        assert!(!rep.partial, "healthy fleet answered partial");
        assert_eq!(rep.ids.len(), PER_REQ, "every draw must be answered");
        for (t, &id) in rep.ids.iter().enumerate() {
            counts[id as usize] += 1;
            // spot-check the merged log q against the exact distribution
            // (shard log q + shard-mass correction must recompose to the
            // global log-probability)
            if t % 997 == 0 {
                let expect = (probs[id as usize] as f64).ln() as f32;
                let got = rep.scores[t];
                assert!(
                    (got - expect).abs() <= 1e-3 * (1.0 + expect.abs()),
                    "draw {t}: log q {got} vs exact {expect}"
                );
                checked += 1;
            }
        }
    }
    assert!(checked > 0);
    let draws = (2 * PER_REQ) as u64;
    let (stat, df) = chi_square_gof(&counts, &probs, draws);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): merged remote draws diverge from the \
         exact softmax"
    );
}

// -- failure ---------------------------------------------------------------

#[test]
fn killed_shard_degrades_to_partial_within_the_deadline() {
    let (n, d) = (300usize, 6usize);
    let snap = snapshot(n, d, 0xDEAD);
    let (mut procs, _manifest) = spawn_fleet(&snap, 3, "kill");
    let deadline = Duration::from_millis(1500);
    let remote = router(&procs, deadline);

    // SIGKILL shard 1: no goodbye, no FIN until the kernel reaps it
    procs[1].child.kill().expect("killing shard 1");
    procs[1].child.wait().expect("reaping shard 1");

    let t0 = Instant::now();
    let rep = &remote.run_requests(&[Request::TopK { q: q_vec(3, 0, d), k: 8 }])[0];
    let elapsed = t0.elapsed();
    assert!(
        elapsed < deadline + Duration::from_secs(5),
        "query took {elapsed:?} — the deadline must bound a dead shard's damage"
    );
    assert!(rep.partial, "a dead shard must flag the merged answer partial");
    assert!(rep.error.is_none(), "degraded, not failed: {:?}", rep.error);
    assert!(rep.ids.iter().all(|&c| (c as usize) < n));
    let (live, total) = remote.shard_info();
    assert_eq!(total, 3);
    assert!(live < 3, "the dead shard's connection must have been dropped");

    // the fleet keeps answering (partial) on subsequent queries too
    let rep = &remote.run_requests(&[Request::Mass { q: q_vec(4, 0, d) }])[0];
    assert!(rep.partial);
    assert_eq!(rep.scores.len(), 1);
    assert!(rep.scores[0].is_finite());
}

// -- generation pinning ----------------------------------------------------

#[test]
fn mid_push_mixed_generations_refuse_to_merge() {
    let (n, d) = (200usize, 6usize);
    let snap = snapshot(n, d, 0xA11E);
    let (procs, manifest) = spawn_fleet(&snap, 2, "gen");
    let remote = router(&procs, Duration::from_secs(30));

    let q = q_vec(5, 0, d);
    let rep = &remote.run_requests(&[Request::TopK { q: q.clone(), k: 6 }])[0];
    assert!(rep.error.is_none());
    assert_eq!(rep.generation, 0);

    // push shard 0's own slice back at it as a whole-snapshot live update:
    // the model is unchanged but its generation becomes 1, so the fleet is
    // now mid-push (gen 1 + gen 0)
    let push = |si: usize| {
        let file = manifest.with_file_name(format!("fleet.midx.shard{si}"));
        let status = Command::new(env!("CARGO_BIN_EXE_midx"))
            .args([
                "push-update",
                "--addr",
                &procs[si].addr,
                "--next",
                file.to_str().unwrap(),
            ])
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .status()
            .expect("running midx push-update");
        assert!(status.success(), "push-update to shard {si} failed");
    };
    push(0);

    let rep = &remote.run_requests(&[Request::TopK { q: q.clone(), k: 6 }])[0];
    let err = rep.error.as_deref().unwrap_or_else(|| {
        panic!("mixed-generation merge must refuse, got ids={:?}", rep.ids)
    });
    assert!(err.contains("generation"), "refusal must name the cause: {err}");
    assert!(rep.ids.is_empty(), "a refused merge must carry no data");

    // sampling refuses too (the mass wave already spans both generations)
    let rep = &remote.run_requests(&[Request::Sample {
        q: q.clone(),
        m: 32,
        seed: 7,
        fallback: false,
    }])[0];
    assert!(rep.error.is_some(), "mixed-generation sample must refuse");

    // once the push reaches the whole fleet, merges resume on the new
    // generation — and the answers match the pre-push model bit-for-bit
    // (the pushed snapshot was the same slice)
    let before = remote.run_requests(&[Request::TopK { q: q.clone(), k: 6 }]);
    assert!(before[0].error.is_some());
    push(1);
    let rep = &remote.run_requests(&[Request::TopK { q, k: 6 }])[0];
    assert!(rep.error.is_none(), "settled fleet must merge again: {:?}", rep.error);
    assert_eq!(rep.generation, 1, "merges must pin on the fleet's new generation");
    assert!(!rep.partial);
    assert!(!rep.ids.is_empty());
}
