//! Shard-equivalence & fault-injection harness for the scatter-gather
//! serving tier (`serve::shard`).
//!
//! What it proves (ISSUE 8 acceptance):
//!
//! * **Merged top-k is bit-identical**: at full beam, the router's merged
//!   top-k — ids *and* score bits — equals the monolithic engine's for
//!   S ∈ {1, 2, 4} shards × T ∈ {1, 8} worker threads, and a one-shard
//!   router matches at the default beam too.
//! * **Merged draws are distributed identically**: ≥100k draws routed
//!   through shard-mass selection + per-shard delegation pass a Pearson
//!   χ² goodness-of-fit test against the exact softmax (exact-midx
//!   shards) and against the monolithic core's own proposal (fast
//!   midx-rq shards); merged log proposals match the exact distribution
//!   pointwise.
//! * **Degenerate splits merge exactly** (property-tested): empty shards,
//!   one-class shards and the all-classes-in-one-shard split all
//!   reproduce the monolithic top-k bit-for-bit, and per-shard partition
//!   masses compose to the monolithic mass (`Z = Σ_s Z_s`).
//! * **A down shard is never a silent wrong answer**: dropping a shard
//!   flags every affected reply partial (engine-level and through the
//!   served JSON protocol), serves exactly the monolithic answer
//!   restricted to live classes, and redistributes draws to the live
//!   shards' renormalized distribution.
//! * **The on-disk contract holds**: `export_shards` → `load` round-trips
//!   bit-identically under eager and mmap loads; checksum mismatches,
//!   missing files and malformed manifests (count mismatch, overlap, gap,
//!   bad checksum syntax) are rejected with the manifest path and the
//!   offending shard index in the error; a missing file degrades to a
//!   flagged partial router only under `allow_missing`.

use std::sync::Arc;
use std::time::Duration;

use midx::sampler::{SamplerKind, Scratch};
use midx::serve::shard::load_router;
use midx::serve::snapshot::fnv1a64;
use midx::serve::update::b64_encode;
use midx::serve::{
    export_shards, handle_line, shard_ranges, LatencyRecorder, LoadMode, MicroBatcher,
    QueryEngine, ShardManifest, ShardRouter, UpdateConfig, UpdateHub, UpdateSession,
};
use midx::stats::divergence::{chi_square_critical, chi_square_gof, softmax_dist};
use midx::util::check::for_all;

mod common;
use common::{q_json, q_vec, snapshot, snapshot_of};

/// Score vectors compared as exact bit patterns (the suite pins
/// bit-identity, not approximate equality).
fn bits(scores: &[f32]) -> Vec<u32> {
    scores.iter().map(|x| x.to_bits()).collect()
}

/// A [B, D] query block from the shared deterministic corpus.
fn query_block(b: usize, d: usize) -> Vec<f32> {
    (0..b).flat_map(|r| q_vec(3, r, d)).collect()
}

// -- bit-identity ----------------------------------------------------------

#[test]
fn merged_top_k_is_bit_identical_at_full_beam() {
    let (n, d, k) = (60usize, 8usize, 10usize);
    let snap = snapshot(n, d, 0x5AAD);
    let queries = query_block(16, d);
    for &s in &[1usize, 2, 4] {
        for &t in &[1usize, 8] {
            let mut mono = QueryEngine::new(snap.clone(), t).unwrap();
            mono.set_beam_factor(usize::MAX);
            let mut router = ShardRouter::split(&snap, s, t).unwrap();
            router.set_beam_factor(usize::MAX);
            let (mi, ms) = mono.top_k_batch(&queries, k);
            let (ri, rs, partial) = router.top_k_batch(&queries, k);
            assert!(!partial, "healthy router must not flag partial (S={s} T={t})");
            assert_eq!(mi, ri, "merged ids diverge (S={s} T={t})");
            assert_eq!(bits(&ms), bits(&rs), "merged score bits diverge (S={s} T={t})");

            // the single-query path merges identically too
            let z = q_vec(9, s + t, d);
            let (pairs, partial) = router.top_k(&z, k);
            assert!(!partial);
            assert_eq!(pairs, mono.top_k(&z, k), "single-query merge (S={s} T={t})");
        }
    }
}

#[test]
fn one_shard_router_matches_monolithic_at_default_beam() {
    let (n, d, k) = (60usize, 8usize, 7usize);
    let snap = snapshot(n, d, 0x1B0B);
    let mono = QueryEngine::new(snap.clone(), 1).unwrap();
    let router = ShardRouter::split(&snap, 1, 1).unwrap();
    let queries = query_block(8, d);
    let (mi, ms) = mono.top_k_batch(&queries, k);
    let (ri, rs, partial) = router.top_k_batch(&queries, k);
    assert!(!partial);
    assert_eq!(mi, ri, "S=1 default-beam ids");
    assert_eq!(bits(&ms), bits(&rs), "S=1 default-beam score bits");
}

// -- distribution ----------------------------------------------------------

#[test]
fn merged_draws_match_the_exact_softmax() {
    // exact-midx shards: the merged proposal IS the softmax (Theorem 1
    // per shard + exact mass composition), so ≥100k merged draws must
    // pass a χ² GOF against softmax(z·Qᵀ) directly.
    let (n, d) = (48usize, 8usize);
    let snap = snapshot_of(SamplerKind::ExactMidx, n, d, 0xE5A7);
    let z = q_vec(7, 1, d);
    let probs = softmax_dist(&z, &snap.table, n, d);
    let router = ShardRouter::split(&snap, 3, 1).unwrap();

    const DRAWS: usize = 120_000;
    let (ids, log_q, partial) = router.sample(&z, DRAWS, 0xFEED);
    assert!(!partial);
    assert_eq!(ids.len(), DRAWS, "every draw must be answered");

    let mut counts = vec![0u64; n];
    for &c in &ids {
        counts[c as usize] += 1;
    }
    let (stat, df) = chi_square_gof(&counts, &probs, DRAWS as u64);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): merged draws diverge from the exact softmax"
    );

    // the ln(Z_s / Z) correction must hand back the *global* log proposal
    for (j, (&c, &lq)) in ids.iter().zip(&log_q).enumerate().step_by(997) {
        let expect = probs[c as usize].ln();
        assert!(
            (lq - expect).abs() <= 1e-3 * (1.0 + expect.abs()),
            "draw {j}: merged log q {lq} vs exact {expect} for class {c}"
        );
    }
}

#[test]
fn merged_draws_match_the_monolithic_fast_proposal() {
    // fast midx-rq shards: the merged draws must follow the monolithic
    // core's own proposal distribution (Theorem 2's quantized softmax).
    let (n, d) = (48usize, 8usize);
    let snap = snapshot(n, d, 0xC5A7);
    let mono = QueryEngine::new(snap.clone(), 1).unwrap();
    let z = q_vec(5, 2, d);
    let mut probs = vec![0.0f32; n];
    mono.core().proposal_dist(&z, &mut Scratch::new(), &mut probs);
    let router = ShardRouter::split(&snap, 4, 1).unwrap();

    const DRAWS: usize = 120_000;
    let (ids, _log_q, partial) = router.sample(&z, DRAWS, 0xFA57);
    assert!(!partial);
    let mut counts = vec![0u64; n];
    for &c in &ids {
        counts[c as usize] += 1;
    }
    let (stat, df) = chi_square_gof(&counts, &probs, DRAWS as u64);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): merged draws diverge from the monolithic \
         proposal"
    );
}

// -- degenerate splits (property) ------------------------------------------

#[test]
fn prop_degenerate_splits_merge_exactly() {
    for_all("degenerate shard splits merge exactly", |rng, case| {
        let n = 12 + rng.below(24);
        let d = 4 + 2 * rng.below(3);
        let snap = snapshot(n, d, 0xDE6E + case);
        let mid = 1 + rng.below(n - 1);
        let ranges: Vec<(usize, usize)> = match case % 5 {
            0 => vec![(0, 0), (0, n)],                    // empty shard in front
            1 => vec![(0, mid), (mid, mid), (mid, n)],    // empty shard in the middle
            2 => vec![(0, n), (n, n)],                    // empty shard at the end
            3 => vec![(0, 1), (1, n)],                    // one-class shard
            _ => vec![(0, n)],                            // everything in one shard
        };
        let mut router = ShardRouter::from_snapshot(&snap, &ranges, 1)
            .map_err(|e| format!("building router over {ranges:?}: {e}"))?;
        router.set_beam_factor(usize::MAX);
        let mut mono = QueryEngine::new(snap.clone(), 1).map_err(|e| e.to_string())?;
        mono.set_beam_factor(usize::MAX);

        // merged top-k over the whole class space, bit-for-bit
        let z = q_vec(1, case as usize, d);
        let k = n.min(5 + rng.below(8));
        let (pairs, partial) = router.top_k(&z, k);
        if partial {
            return Err("empty shards must not flag partial".into());
        }
        let expect = mono.top_k(&z, k);
        if pairs != expect {
            return Err(format!("split {ranges:?}: merged {pairs:?} != monolithic {expect:?}"));
        }

        // per-shard masses compose exactly: ln Σ_s Z_s == ln Z
        let mut scratch = Scratch::new();
        let mono_mass = mono.log_partition_mass(&z, &mut scratch) as f64;
        let mut total = 0.0f64;
        for &(lo, hi) in &ranges {
            if lo == hi {
                continue;
            }
            let slice = midx::serve::slice_snapshot(&snap, lo, hi).map_err(|e| e.to_string())?;
            let eng = QueryEngine::new(slice, 1).map_err(|e| e.to_string())?;
            total += (eng.log_partition_mass(&z, &mut scratch) as f64).exp();
        }
        midx::util::check::close(total.ln(), mono_mass, 1e-3, "mass composition")?;

        // merged draws stay in range and carry finite log proposals
        let (ids, log_q, partial) = router.sample(&z, 32, 0xD0 + case);
        if partial {
            return Err("healthy degenerate split flagged partial".into());
        }
        for (&c, &lq) in ids.iter().zip(&log_q) {
            if c as usize >= n || !lq.is_finite() || lq > 0.0 {
                return Err(format!("draw ({c}, {lq}) out of range for n={n}"));
            }
        }
        Ok(())
    });
}

// -- fault injection -------------------------------------------------------

#[test]
fn down_shard_flags_partial_and_serves_exactly_the_live_classes() {
    let (n, d, k) = (60usize, 8usize, 8usize);
    let snap = snapshot(n, d, 0xD0A0);
    let mut mono = QueryEngine::new(snap.clone(), 1).unwrap();
    mono.set_beam_factor(usize::MAX);
    let mut router = ShardRouter::split(&snap, 3, 1).unwrap();
    router.set_beam_factor(usize::MAX);

    let (lo, hi) = router.shard_range(1);
    router.drop_shard(1);
    assert!(router.degraded());
    assert_eq!(router.live_shards(), 2);

    // top-k: the monolithic ranking with the dead shard's classes removed —
    // never a silently wrong (re-ranked or missing-flag) answer
    let z = q_vec(2, 9, d);
    let (pairs, partial) = router.top_k(&z, k);
    assert!(partial, "down shard must flag partial");
    let expect: Vec<(u32, f32)> = mono
        .top_k(&z, n)
        .into_iter()
        .filter(|(c, _)| !(lo..hi).contains(&(*c as usize)))
        .take(k)
        .collect();
    assert_eq!(pairs, expect, "degraded top-k must equal the live-restricted ranking");

    // draws: none from the dead range, distributed as the live-renormalized
    // proposal (shard-mass composition makes that the exact conditional)
    let mut probs = vec![0.0f32; n];
    mono.core().proposal_dist(&z, &mut Scratch::new(), &mut probs);
    for p in &mut probs[lo..hi] {
        *p = 0.0;
    }
    let total: f32 = probs.iter().sum();
    for p in &mut probs {
        *p /= total;
    }
    const DRAWS: usize = 40_000;
    let (ids, _lq, partial) = router.sample(&z, DRAWS, 0xDEAD);
    assert!(partial);
    let mut counts = vec![0u64; n];
    for &c in &ids {
        assert!(
            !(lo..hi).contains(&(c as usize)),
            "draw from down shard's class {c} (range {lo}..{hi})"
        );
        counts[c as usize] += 1;
    }
    let (stat, df) = chi_square_gof(&counts, &probs, DRAWS as u64);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): degraded draws diverge from the \
         live-renormalized proposal"
    );
}

#[test]
fn partial_flag_travels_through_the_served_protocol() {
    let (n, d) = (60usize, 8usize);
    let snap = snapshot(n, d, 0xF1A6);
    let rec = LatencyRecorder::new();
    let line = format!(r#"{{"op":"topk","q":{},"k":5}}"#, q_json(4, 0, d));
    let sample_line = format!(r#"{{"op":"sample","q":{},"m":6,"seed":77}}"#, q_json(4, 1, d));

    // healthy sharded backend: replies carry no partial key at all (the
    // wire format stays byte-compatible with the monolithic server)
    let healthy = ShardRouter::split(&snap, 3, 1).unwrap();
    let batcher = MicroBatcher::new(Arc::new(healthy), Duration::ZERO, 16);
    for l in [&line, &sample_line] {
        let reply = handle_line(&batcher, &rec, l);
        assert!(reply.contains(r#""ok":true"#), "{reply}");
        assert!(!reply.contains("partial"), "healthy reply must not mention partial: {reply}");
    }
    let info = handle_line(&batcher, &rec, r#"{"op":"info"}"#);
    assert!(info.contains(r#""shards":3"#), "{info}");
    assert!(info.contains(r#""shards_live":3"#), "{info}");

    // degraded backend: every affected reply says so explicitly
    let mut degraded = ShardRouter::split(&snap, 3, 1).unwrap();
    degraded.drop_shard(2);
    let batcher = MicroBatcher::new(Arc::new(degraded), Duration::ZERO, 16);
    for l in [&line, &sample_line] {
        let reply = handle_line(&batcher, &rec, l);
        assert!(reply.contains(r#""ok":true"#), "{reply}");
        assert!(
            reply.contains(r#""partial":true"#),
            "degraded reply must flag partial: {reply}"
        );
    }
    let info = handle_line(&batcher, &rec, r#"{"op":"info"}"#);
    assert!(info.contains(r#""shards":3"#), "{info}");
    assert!(info.contains(r#""shards_live":2"#), "{info}");

}

#[test]
fn sharded_backends_refuse_live_updates_explicitly() {
    // the update seam (PR 7) rebuilds from the live engine's snapshot,
    // which a sharded backend does not have — the commit must fail with a
    // descriptive error, not silently corrupt or no-op
    let (n, d) = (40usize, 6usize);
    let snap = snapshot(n, d, 0x0BAD);
    let router = ShardRouter::split(&snap, 2, 1).unwrap();
    let batcher = Arc::new(MicroBatcher::new(Arc::new(router), Duration::ZERO, 8));
    let hub = UpdateHub::new(Arc::clone(&batcher), UpdateConfig::default());
    let mut sess = UpdateSession::new(hub);
    let rec = LatencyRecorder::new();

    let payload = snap.to_bytes();
    let begin = format!(
        r#"{{"op":"update","action":"begin","mode":"snapshot","bytes":{},"chunks":1}}"#,
        payload.len()
    );
    let chunk =
        format!(r#"{{"op":"update","action":"chunk","seq":0,"data":"{}"}}"#, b64_encode(&payload));
    let commit = format!(r#"{{"op":"update","action":"commit","fnv":"{:016x}"}}"#, fnv1a64(&payload));
    assert!(sess.handle(&rec, &begin).contains(r#""ok":true"#));
    assert!(sess.handle(&rec, &chunk).contains(r#""ok":true"#));
    let reply = sess.handle(&rec, &commit);
    assert!(reply.contains(r#""ok":false"#), "{reply}");
    assert!(reply.contains("monolithic"), "rejection must say why: {reply}");

    // the sharded backend keeps serving, un-degraded, after the refusal
    let probe = format!(r#"{{"op":"topk","q":{},"k":4}}"#, q_json(6, 3, d));
    let after = sess.handle(&rec, &probe);
    assert!(after.contains(r#""ok":true"#), "{after}");
    assert!(!after.contains("partial"), "{after}");
}

// -- the on-disk contract --------------------------------------------------

/// A scratch directory unique to this test process; removed on drop.
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("midx_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }

    fn path(&self, name: &str) -> std::path::PathBuf {
        self.0.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn export_load_round_trip_is_bit_identical_and_fault_injectable() {
    let dir = TempDir::new("shard_roundtrip");
    let (n, d, k) = (60usize, 8usize, 9usize);
    let snap = snapshot(n, d, 0x0D15C);
    let manifest_path = dir.path("snap.midx");
    let manifest = export_shards(&snap, 3, &manifest_path).unwrap();
    assert_eq!(manifest.shards.len(), 3);
    assert_eq!(manifest.n, n);
    assert_eq!(ShardManifest::read(&manifest_path).unwrap(), manifest, "manifest round-trip");

    let mut mono = QueryEngine::new(snap.clone(), 1).unwrap();
    mono.set_beam_factor(usize::MAX);
    let queries = query_block(6, d);
    let (mi, ms) = mono.top_k_batch(&queries, k);

    // eager and mmap loads both serve the monolithic answer, bit-for-bit
    for mode in [LoadMode::Eager, LoadMode::Mmap] {
        let mut router = load_router(&manifest_path, mode, 1, false).unwrap();
        router.set_beam_factor(usize::MAX);
        let (ri, rs, partial) = router.top_k_batch(&queries, k);
        assert!(!partial);
        assert_eq!(mi, ri, "{} load ids", mode.name());
        assert_eq!(bits(&ms), bits(&rs), "{} load score bits", mode.name());
    }

    // corrupt shard 1: the eager load must name the manifest, the shard
    // index and both checksums — and allow_missing must NOT skip it
    // (corruption is never "missing")
    let shard1 = dir.path(&manifest.shards[1].file);
    let good = std::fs::read(&shard1).unwrap();
    let mut bad = good.clone();
    bad.push(0xA5);
    std::fs::write(&shard1, &bad).unwrap();
    for allow_missing in [false, true] {
        let err = load_router(&manifest_path, LoadMode::Eager, 1, allow_missing)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("shard 1 checksum mismatch"),
            "allow_missing={allow_missing}: {err}"
        );
        assert!(err.contains("snap.midx"), "error must carry the manifest path: {err}");
    }
    std::fs::write(&shard1, &good).unwrap();

    // delete shard 2: a hard error without allow_missing (naming the shard
    // and the path), a flagged degraded router with it
    let shard2 = dir.path(&manifest.shards[2].file);
    let (lo2, hi2) = (manifest.shards[2].lo, manifest.shards[2].hi);
    std::fs::remove_file(&shard2).unwrap();
    let err = load_router(&manifest_path, LoadMode::Eager, 1, false).unwrap_err().to_string();
    assert!(err.contains("shard 2"), "{err}");
    assert!(err.contains("snap.midx"), "{err}");

    let mut degraded = load_router(&manifest_path, LoadMode::Eager, 1, true).unwrap();
    degraded.set_beam_factor(usize::MAX);
    assert!(degraded.degraded());
    assert_eq!(degraded.live_shards(), 2);
    assert_eq!(degraded.shard_count(), 3);
    let (ri, _rs, partial) = degraded.top_k_batch(&queries, k);
    assert!(partial, "a router missing a shard must flag every answer partial");
    for &c in &ri {
        assert!(
            !(lo2..hi2).contains(&(c as usize)),
            "degraded load answered class {c} from the missing shard"
        );
    }
}

#[test]
fn malformed_manifests_are_rejected_with_path_and_shard_context() {
    let dir = TempDir::new("shard_manifest_neg");

    // entries with plausible shapes; checksums are syntactically fine (the
    // files are never opened — structural validation fails first)
    let entry = |i: usize, lo: usize, hi: usize| {
        format!(r#"{{"file":"m.shard{i}","lo":{lo},"hi":{hi},"fnv":"00000000000000aa"}}"#)
    };
    let manifest = |count: usize, entries: &[String]| {
        format!(
            r#"{{"midx_shard_manifest":1,"kind":"midx-rq","n":60,"d":8,"count":{count},"shards":[{}]}}"#,
            entries.join(",")
        )
    };

    let cases: Vec<(&str, String, &str)> = vec![
        (
            "count mismatch",
            manifest(3, &[entry(0, 0, 30), entry(1, 30, 60)]),
            "shard count mismatch: manifest declares count=3 but lists 2 shards",
        ),
        (
            "overlap",
            manifest(2, &[entry(0, 0, 35), entry(1, 30, 60)]),
            "shard 1: class range [30,60) overlaps shard 0",
        ),
        (
            "gap",
            manifest(2, &[entry(0, 0, 20), entry(1, 30, 60)]),
            "shard 1: gap in class coverage — classes 20..30 belong to no shard",
        ),
        (
            "short cover",
            manifest(2, &[entry(0, 0, 20), entry(1, 20, 50)]),
            "shards cover classes 0..50 but the snapshot has 60",
        ),
        (
            "empty range",
            manifest(2, &[entry(0, 0, 0), entry(1, 0, 60)]),
            "shard 0: bad class range [0,0)",
        ),
        (
            "bad checksum syntax",
            manifest(
                1,
                &[r#"{"file":"m.shard0","lo":0,"hi":60,"fnv":"not-hex"}"#.to_string()],
            ),
            "shard 0: bad fnv checksum 'not-hex'",
        ),
        (
            "missing marker",
            r#"{"kind":"midx-rq","n":60,"d":8,"count":1,"shards":[]}"#.to_string(),
            "not a midx shard manifest",
        ),
    ];

    for (tag, text, want) in cases {
        let path = dir.path(&format!("{}.midx", tag.replace(' ', "_")));
        std::fs::write(&path, text).unwrap();
        let err = ShardManifest::read(&path).unwrap_err().to_string();
        assert!(err.contains(want), "{tag}: error {err:?} must contain {want:?}");
        assert!(
            err.contains(&path.display().to_string()),
            "{tag}: error must carry the manifest path: {err}"
        );
        // the router load path surfaces the same context
        let err = load_router(&path, LoadMode::Eager, 1, true).unwrap_err().to_string();
        assert!(err.contains(want), "{tag} via load: {err}");
    }
}

// -- export surface --------------------------------------------------------

#[test]
fn shard_ranges_refuse_nonsense_and_exports_cover_everything() {
    assert!(shard_ranges(10, 0).is_err());
    assert!(shard_ranges(3, 4).is_err());
    let r = shard_ranges(10, 4).unwrap();
    assert_eq!(r, vec![(0, 3), (3, 6), (6, 8), (8, 10)]);

    // exporting S=1 still writes a valid manifest + one shard file that
    // serves the whole class space
    let dir = TempDir::new("shard_single");
    let (n, d) = (30usize, 6usize);
    let snap = snapshot(n, d, 0x51E6);
    let path = dir.path("one.midx");
    let manifest = export_shards(&snap, 1, &path).unwrap();
    assert_eq!(manifest.shards.len(), 1);
    assert_eq!((manifest.shards[0].lo, manifest.shards[0].hi), (0, n));
    let router = load_router(&path, LoadMode::Eager, 1, false).unwrap();
    assert_eq!(router.n_classes(), n);
    assert_eq!(router.live_shards(), 1);
}
