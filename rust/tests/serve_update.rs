//! Update-under-load correctness harness for zero-downtime live model
//! updates (`serve::update`).
//!
//! What it proves (ISSUE 7 acceptance):
//!
//! * **Updates never drop a query**: N multiplexed connections pipeline
//!   topk/sample bursts while K delta updates stream in on another
//!   connection — every request is answered exactly once, every update
//!   frame is acknowledged in order, and the commit replies report
//!   monotonically increasing generations.
//! * **Post-swap state is bit-identical to a cold load**: after the last
//!   swap, served replies are byte-identical (modulo the `us` field) to a
//!   freshly constructed engine over the locally folded snapshot — the
//!   same pure [`apply_to_snapshot`] the server ran against its shadow
//!   copy — at both T = 1 and T = 8 worker threads.
//! * **Statistics survive the swap**: draws taken entirely after the last
//!   swap pass a Pearson χ² goodness-of-fit test against the updated
//!   core's own proposal distribution.
//! * **The swap seam is atomic**: `swap_engine` under concurrent
//!   submitters never loses, duplicates, or corrupts a reply — every
//!   reply is bit-identical to one of the two engine states.
//! * **Rejection is safe**: truncated/corrupt payloads, checksum
//!   mismatches, out-of-order chunks, oversize declarations, and
//!   mid-update client disconnects all leave the old core serving,
//!   bit-identical to before, at generation 0.
//!
//! The reactor is unix-only (raw `poll(2)`), so this whole suite is too.
#![cfg(unix)]

use std::io::{BufReader, Write};
use std::sync::Arc;
use std::time::Duration;

use midx::sampler::Scratch;
use midx::serve::snapshot::fnv1a64;
use midx::serve::update::{apply_to_snapshot, b64_encode};
use midx::serve::{
    handle_line, Delta, LatencyRecorder, MicroBatcher, QueryEngine, ReactorConfig, Snapshot,
    UpdateConfig, UpdateHub, UpdateSession,
};
use midx::stats::divergence::{chi_square_critical, chi_square_gof};
use midx::util::{Json, Rng};

mod common;
use common::{connect, engine, read_replies, request_line, serve, strip_us, Conn};

/// A deterministic delta moving every 5th row (phase `which`) of `base`
/// to fresh random values.
fn delta_for(base: &Snapshot, which: u64) -> Delta {
    let d = base.d;
    let rows: Vec<u32> = (0..base.n as u32).filter(|r| (*r as u64 + which) % 5 == 0).collect();
    let mut rng = Rng::new(0xDE17A + which);
    let values = midx::util::check::rand_matrix(&mut rng, rows.len(), d, 0.5);
    Delta { d, rows, values }
}

/// The full begin / chunk* / commit line sequence pushing `payload`.
fn update_lines(mode: &str, payload: &[u8], chunk_bytes: usize) -> Vec<String> {
    let chunks: Vec<&[u8]> = payload.chunks(chunk_bytes).collect();
    let mut lines = vec![format!(
        r#"{{"op":"update","action":"begin","mode":"{mode}","bytes":{},"chunks":{}}}"#,
        payload.len(),
        chunks.len()
    )];
    for (i, c) in chunks.iter().enumerate() {
        lines.push(format!(
            r#"{{"op":"update","action":"chunk","seq":{i},"data":"{}"}}"#,
            b64_encode(c)
        ));
    }
    lines
        .push(format!(r#"{{"op":"update","action":"commit","fnv":"{:016x}"}}"#, fnv1a64(payload)));
    lines
}

/// Push `payload` over `conn`, asserting every ack, and return the commit
/// reply.
fn push_update(conn: &mut Conn, mode: &str, payload: &[u8], chunk_bytes: usize) -> String {
    let lines = update_lines(mode, payload, chunk_bytes);
    let last = lines.len() - 1;
    let mut commit = String::new();
    for (i, line) in lines.iter().enumerate() {
        let reply = conn.send(line);
        assert!(reply.contains(r#""ok":true"#), "update frame {i} refused: {reply}");
        if i == last {
            assert!(reply.contains(r#""update":"commit""#), "{reply}");
            commit = reply;
        }
    }
    commit
}

// -- the update-under-load soak --------------------------------------------

#[test]
fn live_updates_under_load_swap_to_bit_identical_state() {
    const CLIENTS: usize = 8;
    const WAVES: usize = 4;
    const PER_WAVE: usize = 10;
    const UPDATES: usize = 3;
    let (n, d) = (60usize, 8usize);
    let eng = engine(n, d, 0x0DDA7E, 2);
    let base = eng.capture_snapshot();
    let cfg = UpdateConfig::default();

    // K deltas, and the expected final snapshot folded locally with the
    // very same pure apply the server runs against its shadow copy
    let deltas: Vec<Delta> = (0..UPDATES as u64).map(|k| delta_for(&base, k)).collect();
    let mut expect = base;
    for delta in &deltas {
        let (next, outcome) = apply_to_snapshot(&expect, &delta.to_bytes(), &cfg).unwrap();
        assert!(outcome.drifted > 0, "a delta must actually move rows");
        expect = next;
    }

    let batcher = Arc::new(MicroBatcher::with_queue_cap(
        Arc::clone(&eng),
        Duration::from_micros(200),
        64,
        8192,
    ));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig {
            max_conns: CLIENTS + 8,
            idle_timeout: Duration::ZERO,
            ..Default::default()
        },
    );
    let addr = served.addr;

    // load clients: pipeline in waves so queries are in flight across swaps
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut stream = connect(addr);
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut got = 0usize;
                for w in 0..WAVES {
                    let burst: String = (0..PER_WAVE)
                        .map(|i| request_line(c, w * PER_WAVE + i, d) + "\n")
                        .collect();
                    stream.write_all(burst.as_bytes()).unwrap();
                    stream.flush().unwrap();
                    let who = format!("load client {c} wave {w}");
                    for r in read_replies(&mut reader, PER_WAVE, &who) {
                        assert!(r.contains(r#""ok":true"#), "client {c}: {r}");
                        got += 1;
                    }
                }
                got
            })
        })
        .collect();

    // updater: stream the K deltas in while the load runs
    let payloads: Vec<Vec<u8>> = deltas.iter().map(Delta::to_bytes).collect();
    let updater = std::thread::spawn(move || {
        let mut conn = Conn::open(addr);
        for (k, payload) in payloads.iter().enumerate() {
            std::thread::sleep(Duration::from_millis(15));
            let commit = push_update(&mut conn, "delta", payload, 96);
            assert!(
                commit.contains(&format!(r#""generation":{}"#, k + 1)),
                "update {k}: {commit}"
            );
            assert!(commit.contains(r#""swap_us":"#), "{commit}");
        }
    });

    updater.join().expect("updater thread");
    let mut answered = 0usize;
    for h in clients {
        answered += h.join().expect("load client");
    }
    assert_eq!(answered, CLIENTS * WAVES * PER_WAVE, "exactly one reply per request");
    let (accepted, _) = served.batcher.stats();
    assert_eq!(accepted, (CLIENTS * WAVES * PER_WAVE) as u64, "updates ride past the batcher");
    assert_eq!(served.batcher.rejected(), 0);

    // post-swap: served replies are bit-identical to a cold load of the
    // locally folded snapshot, at both a serial and a parallel engine
    for &threads in &[1usize, 8] {
        let cold = Arc::new(QueryEngine::new(expect.clone(), threads).unwrap());
        let solo = MicroBatcher::new(cold, Duration::ZERO, 1);
        let solo_rec = LatencyRecorder::new();
        let mut conn = Conn::open(addr);
        for c in 0..4 {
            for j in 0..12 {
                let line = request_line(100 + c, j, d);
                let want = strip_us(&handle_line(&solo, &solo_rec, &line));
                let got = strip_us(&conn.send(&line));
                assert_eq!(
                    got, want,
                    "post-swap reply diverges from cold load (T={threads}, c={c}, j={j})"
                );
            }
        }
    }

    // the served engine owns up to its lineage
    let mut conn = Conn::open(addr);
    let info = conn.send(r#"{"op":"info"}"#);
    assert!(info.contains(&format!(r#""generation":{UPDATES}"#)), "{info}");
    let stats = conn.send(r#"{"op":"stats"}"#);
    assert!(stats.contains(r#""updates_applied":3"#), "{stats}");
    assert!(stats.contains(r#""updates_rejected":0"#), "{stats}");
    served.stop();
}

#[test]
fn post_swap_draw_statistics_match_the_updated_core() {
    const CLIENTS: usize = 2;
    const REQS: usize = 24;
    const M: usize = 500; // 2 × 24 × 500 = 24k draws, all after the swap
    let (n, d) = (48usize, 8usize);
    let eng = engine(n, d, 0xC4A9, 2);
    let base = eng.capture_snapshot();
    let cfg = UpdateConfig::default();
    let delta = delta_for(&base, 9);
    let (expect, _) = apply_to_snapshot(&base, &delta.to_bytes(), &cfg).unwrap();

    // one fixed query; its JSON text round-trips to the exact f32s below
    let z: Vec<f32> = {
        let mut rng = Rng::new(0x22);
        midx::util::check::rand_matrix(&mut rng, 1, d, 0.5)
    };
    let z_json =
        format!("[{}]", z.iter().map(|x| format!("{x}")).collect::<Vec<_>>().join(","));

    // the UPDATED core's own claim about Q(·|z)
    let cold = QueryEngine::new(expect, 1).unwrap();
    let mut q = vec![0.0f32; n];
    cold.core().proposal_dist(&z, &mut Scratch::new(), &mut q);

    let batcher = Arc::new(MicroBatcher::with_queue_cap(
        Arc::clone(&eng),
        Duration::from_micros(200),
        64,
        4096,
    ));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig { idle_timeout: Duration::ZERO, ..Default::default() },
    );
    let addr = served.addr;

    // swap first, draw after: every draw below reflects the new state
    let mut upd = Conn::open(addr);
    let commit = push_update(&mut upd, "delta", &delta.to_bytes(), 128);
    assert!(commit.contains(r#""generation":1"#), "{commit}");

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let z_json = z_json.clone();
            std::thread::spawn(move || {
                let mut stream = connect(addr);
                let burst: String = (0..REQS)
                    .map(|j| {
                        format!(
                            r#"{{"op":"sample","q":{z_json},"m":{M},"seed":{}}}"#,
                            88_000 + c * 1000 + j
                        ) + "\n"
                    })
                    .collect();
                stream.write_all(burst.as_bytes()).unwrap();
                let mut reader = BufReader::new(stream);
                let mut counts = vec![0u64; n];
                for reply in read_replies(&mut reader, REQS, &format!("χ² client {c}")) {
                    let j = Json::parse(&reply).expect("reply is JSON");
                    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{reply}");
                    for id in j.get("ids").and_then(|v| v.as_arr()).expect("ids array") {
                        counts[id.as_usize().unwrap()] += 1;
                    }
                }
                counts
            })
        })
        .collect();

    let mut counts = vec![0u64; n];
    for h in workers {
        for (i, c) in h.join().expect("χ² client").into_iter().enumerate() {
            counts[i] += c;
        }
    }
    let draws = (CLIENTS * REQS * M) as u64;
    assert_eq!(counts.iter().sum::<u64>(), draws, "every draw accounted for");

    let (stat, df) = chi_square_gof(&counts, &q, draws);
    let crit = chi_square_critical(df, 4.5);
    assert!(
        stat < crit,
        "χ²={stat:.1} ≥ crit={crit:.1} (df={df}): post-swap draws diverge from the updated \
         core's proposal distribution"
    );
    served.stop();
}

// -- the swap seam in isolation --------------------------------------------

#[test]
fn engine_swap_under_concurrent_submitters_never_loses_or_duplicates_replies() {
    const SUBMITTERS: usize = 6;
    const REQS: usize = 60;
    const SWAPS: usize = 8;
    let (n, d) = (50usize, 6usize);
    let eng_a = engine(n, d, 0x5EA0, 2);
    let base = eng_a.capture_snapshot();
    let (snap_b, _) =
        apply_to_snapshot(&base, &delta_for(&base, 1).to_bytes(), &UpdateConfig::default())
            .unwrap();
    let eng_b = Arc::new(eng_a.rebuilt(snap_b).unwrap());
    assert_eq!(eng_b.generation(), 1);

    // every reply must be bit-identical to one of the two engine states
    let rec0 = LatencyRecorder::new();
    let solo_a = MicroBatcher::new(Arc::clone(&eng_a), Duration::ZERO, 1);
    let solo_b = MicroBatcher::new(Arc::clone(&eng_b), Duration::ZERO, 1);
    let mut base_a: Vec<Vec<String>> = Vec::with_capacity(SUBMITTERS);
    let mut base_b: Vec<Vec<String>> = Vec::with_capacity(SUBMITTERS);
    for c in 0..SUBMITTERS {
        base_a.push(
            (0..REQS).map(|j| strip_us(&handle_line(&solo_a, &rec0, &request_line(c, j, d)))).collect(),
        );
        base_b.push(
            (0..REQS).map(|j| strip_us(&handle_line(&solo_b, &rec0, &request_line(c, j, d)))).collect(),
        );
    }

    let live = Arc::new(MicroBatcher::new(Arc::clone(&eng_a), Duration::from_micros(100), 32));
    let submitters: Vec<_> = (0..SUBMITTERS)
        .map(|c| {
            let live = Arc::clone(&live);
            let a = base_a[c].clone();
            let b = base_b[c].clone();
            std::thread::spawn(move || {
                let rec = LatencyRecorder::new();
                for j in 0..REQS {
                    let got = strip_us(&handle_line(&live, &rec, &request_line(c, j, d)));
                    assert!(
                        got == a[j] || got == b[j],
                        "submitter {c} req {j}: reply matches neither engine state: {got}"
                    );
                }
            })
        })
        .collect();

    // swap back and forth while the submitters hammer the batcher
    let swapper = {
        let live = Arc::clone(&live);
        let (eng_a, eng_b) = (Arc::clone(&eng_a), Arc::clone(&eng_b));
        std::thread::spawn(move || {
            let mut pauses = Vec::with_capacity(SWAPS);
            for s in 0..SWAPS {
                std::thread::sleep(Duration::from_millis(3));
                let next =
                    if s % 2 == 0 { Arc::clone(&eng_b) } else { Arc::clone(&eng_a) };
                pauses.push(live.swap_engine(next));
            }
            pauses
        })
    };

    for h in submitters {
        h.join().expect("submitter thread");
    }
    let pauses = swapper.join().expect("swapper thread");
    assert_eq!(pauses.len(), SWAPS);
    for (s, p) in pauses.iter().enumerate() {
        assert!(*p < Duration::from_secs(5), "swap {s} paused for {p:?}");
    }
    let (accepted, _) = live.stats();
    assert_eq!(accepted, (SUBMITTERS * REQS) as u64, "every submission admitted exactly once");
    assert_eq!(live.rejected(), 0);
}

// -- rejection / negative paths --------------------------------------------

#[test]
fn rejected_updates_and_disconnects_leave_the_old_core_serving() {
    let (n, d) = (50usize, 6usize);
    let eng = engine(n, d, 0xBAD2, 1);
    let base = eng.capture_snapshot();
    let batcher = Arc::new(MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 16));
    let served = serve(
        Arc::clone(&batcher),
        ReactorConfig {
            idle_timeout: Duration::ZERO,
            update: UpdateConfig { max_bytes: 1 << 16, ..Default::default() },
            ..Default::default()
        },
    );

    // pre-chaos baseline straight off the same engine
    let solo = MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 1);
    let solo_rec = LatencyRecorder::new();
    let probes: Vec<String> = (0..6).map(|j| request_line(3, j, d)).collect();
    let baseline: Vec<String> =
        probes.iter().map(|l| strip_us(&handle_line(&solo, &solo_rec, l))).collect();

    let good = delta_for(&base, 2).to_bytes();
    let begin_for = |payload: &[u8], chunks: usize| {
        format!(
            r#"{{"op":"update","action":"begin","mode":"delta","bytes":{},"chunks":{chunks}}}"#,
            payload.len()
        )
    };
    let chunk_for = |seq: usize, raw: &[u8]| {
        format!(r#"{{"op":"update","action":"chunk","seq":{seq},"data":"{}"}}"#, b64_encode(raw))
    };
    let commit_for =
        |payload: &[u8]| format!(r#"{{"op":"update","action":"commit","fnv":"{:016x}"}}"#, fnv1a64(payload));

    let mut c = Conn::open(served.addr);

    // frames without a begin
    let r = c.send(r#"{"op":"update","action":"chunk","seq":0,"data":"TWFu"}"#);
    assert!(r.contains("chunk without a begin"), "{r}");
    let r = c.send(r#"{"op":"update","action":"commit","fnv":"0000000000000000"}"#);
    assert!(r.contains("commit without a begin"), "{r}");

    // an out-of-order chunk clears the assembly
    assert!(c.send(&begin_for(&good, 2)).contains(r#""update":"begin""#));
    let r = c.send(&chunk_for(1, &good));
    assert!(r.contains("out of order"), "{r}");
    let r = c.send(&commit_for(&good));
    assert!(r.contains("commit without a begin"), "{r}");

    // declaring more than the server's 64 KiB cap is refused up front
    let r = c.send(&format!(
        r#"{{"op":"update","action":"begin","mode":"delta","bytes":{},"chunks":1}}"#,
        1 << 20
    ));
    assert!(r.contains("server limit"), "{r}");

    // checksum mismatch discards the assembled payload
    assert!(c.send(&begin_for(&good, 1)).contains(r#""update":"begin""#));
    assert!(c.send(&chunk_for(0, &good)).contains(r#""update":"chunk""#));
    let r = c.send(r#"{"op":"update","action":"commit","fnv":"0000000000000000"}"#);
    assert!(r.contains("checksum mismatch"), "{r}");

    // truncated payload: fewer bytes assembled than declared
    let r = c.send(&format!(
        r#"{{"op":"update","action":"begin","mode":"delta","bytes":{},"chunks":1}}"#,
        good.len() + 4
    ));
    assert!(r.contains(r#""update":"begin""#), "{r}");
    assert!(c.send(&chunk_for(0, &good)).contains(r#""update":"chunk""#));
    let r = c.send(&commit_for(&good));
    assert!(r.contains("truncated"), "{r}");

    // corrupt payload with a CORRECT checksum survives assembly but is
    // rejected at apply time — the shadow refresh never touches live state
    let garbage = vec![0xA5u8; 64];
    assert!(c.send(&begin_for(&garbage, 1)).contains(r#""update":"begin""#));
    assert!(c.send(&chunk_for(0, &garbage)).contains(r#""update":"chunk""#));
    let r = c.send(&commit_for(&garbage));
    assert!(r.contains("update rejected") && r.contains("bad delta payload"), "{r}");

    // dimension mismatch
    let wrong_d = Delta { d: d + 1, rows: vec![0], values: vec![0.5; d + 1] }.to_bytes();
    assert!(c.send(&begin_for(&wrong_d, 1)).contains(r#""update":"begin""#));
    assert!(c.send(&chunk_for(0, &wrong_d)).contains(r#""update":"chunk""#));
    let r = c.send(&commit_for(&wrong_d));
    assert!(r.contains("update rejected") && r.contains("dimension"), "{r}");

    // out-of-range row id
    let oob = Delta { d, rows: vec![n as u32], values: vec![0.25; d] }.to_bytes();
    assert!(c.send(&begin_for(&oob, 1)).contains(r#""update":"begin""#));
    assert!(c.send(&chunk_for(0, &oob)).contains(r#""update":"chunk""#));
    let r = c.send(&commit_for(&oob));
    assert!(r.contains("update rejected") && r.contains("out of range"), "{r}");

    // mid-update disconnect: the half-assembled payload dies with the conn
    {
        let mut dying = Conn::open(served.addr);
        assert!(dying.send(&begin_for(&good, 2)).contains(r#""update":"begin""#));
        assert!(dying.send(&chunk_for(0, &good[..32])).contains(r#""update":"chunk""#));
        // vanish with the assembly open
    }

    // through all of it the old core kept serving, bit-identical, at gen 0
    let info = c.send(r#"{"op":"info"}"#);
    assert!(info.contains(r#""generation":0"#), "{info}");
    for (l, want) in probes.iter().zip(&baseline) {
        assert_eq!(strip_us(&c.send(l)), *want, "old core must serve unchanged");
    }
    let stats = c.send(r#"{"op":"stats"}"#);
    assert!(stats.contains(r#""updates_applied":0"#), "{stats}");
    assert!(stats.contains(r#""updates_rejected":3"#), "{stats}");

    // and the connection is still healthy enough to push a VALID update
    let commit = push_update(&mut c, "delta", &good, 48);
    assert!(commit.contains(r#""generation":1"#), "{commit}");
    let (expect, _) =
        apply_to_snapshot(&base, &good, &UpdateConfig::default()).unwrap();
    let cold = MicroBatcher::new(Arc::new(QueryEngine::new(expect, 1).unwrap()), Duration::ZERO, 1);
    for l in &probes {
        assert_eq!(
            strip_us(&c.send(l)),
            strip_us(&handle_line(&cold, &solo_rec, l)),
            "post-recovery replies must match a cold load of the pushed state"
        );
    }
    served.stop();
}

// -- the blocking frontends ------------------------------------------------

#[test]
fn blocking_update_session_round_trips_delta_and_snapshot_pushes() {
    let (n, d) = (40usize, 6usize);
    let eng = engine(n, d, 0x5E55, 1);
    let base = eng.capture_snapshot();
    let cfg = UpdateConfig::default();
    let batcher = Arc::new(MicroBatcher::new(Arc::clone(&eng), Duration::ZERO, 8));
    let hub = UpdateHub::new(Arc::clone(&batcher), cfg);
    let mut sess = UpdateSession::new(hub);
    let rec = LatencyRecorder::new();

    // plain queries pass through the session unchanged
    let line = request_line(0, 0, d);
    assert_eq!(
        strip_us(&sess.handle(&rec, &line)),
        strip_us(&handle_line(&batcher, &rec, &line))
    );

    // delta push → generation 1
    let delta = delta_for(&base, 4).to_bytes();
    let (snap1, _) = apply_to_snapshot(&base, &delta, &cfg).unwrap();
    let mut last = String::new();
    for l in update_lines("delta", &delta, 64) {
        last = sess.handle(&rec, &l);
        assert!(last.contains(r#""ok":true"#), "{last}");
    }
    assert!(last.contains(r#""generation":1"#), "{last}");

    // a second begin discards the first; the follow-up chunk has no home
    let begin = format!(
        r#"{{"op":"update","action":"begin","mode":"delta","bytes":{},"chunks":1}}"#,
        delta.len()
    );
    assert!(sess.handle(&rec, &begin).contains(r#""update":"begin""#));
    assert!(sess.handle(&rec, &begin).contains("already in progress"));
    let chunk = format!(r#"{{"op":"update","action":"chunk","seq":0,"data":"{}"}}"#, b64_encode(&delta));
    assert!(sess.handle(&rec, &chunk).contains("chunk without a begin"));

    // replies now bit-identical to a cold load of the locally applied state
    let cold1 =
        MicroBatcher::new(Arc::new(QueryEngine::new(snap1.clone(), 1).unwrap()), Duration::ZERO, 1);
    for j in 0..8 {
        let l = request_line(2, j, d);
        assert_eq!(
            strip_us(&sess.handle(&rec, &l)),
            strip_us(&handle_line(&cold1, &rec, &l)),
            "post-delta reply diverges from cold load (j={j})"
        );
    }

    // whole-snapshot push → generation 2, bit-identical to its cold load
    let (snap2, _) = apply_to_snapshot(&snap1, &delta_for(&snap1, 5).to_bytes(), &cfg).unwrap();
    for l in update_lines("snapshot", &snap2.to_bytes(), 4096) {
        last = sess.handle(&rec, &l);
        assert!(last.contains(r#""ok":true"#), "{last}");
    }
    assert!(last.contains(r#""generation":2"#), "{last}");
    let cold2 =
        MicroBatcher::new(Arc::new(QueryEngine::new(snap2, 1).unwrap()), Duration::ZERO, 1);
    for j in 0..8 {
        let l = request_line(7, j, d);
        assert_eq!(
            strip_us(&sess.handle(&rec, &l)),
            strip_us(&handle_line(&cold2, &rec, &l)),
            "post-snapshot reply diverges from cold load (j={j})"
        );
    }

    // the session's stats op carries the hub counters
    let stats = sess.handle(&rec, r#"{"op":"stats"}"#);
    assert!(stats.contains(r#""updates_applied":2"#), "{stats}");
    assert!(stats.contains(r#""updates_rejected":0"#), "{stats}");
}
