//! Minimal stand-in for the `anyhow` crate (offline build environment — no
//! crates.io). Implements exactly the API subset midx uses: `Error`,
//! `Result`, the `anyhow!` / `bail!` macros, and the `Context` extension
//! trait. Error values carry a message plus a context chain, rendered
//! outer-to-inner like real anyhow's `{:#}` alternate format.

use std::fmt;

/// A string-backed error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Push an outer context message (what `Context::context` does).
    pub fn wrap<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // multi-line cause chain, mirroring anyhow's Debug rendering
        match self.chain.split_first() {
            None => write!(f, "(empty error)"),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// Anything implementing std::error::Error converts via `?`, as in anyhow.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b: Error = anyhow!("value {x} and {}", 9);
        assert_eq!(b.to_string(), "value 7 and 9");
        let c: Error = anyhow!(io_err());
        assert!(c.to_string().contains("gone"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(e.root_message(), "reading manifest");
        assert!(e.to_string().starts_with("reading manifest: "));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn with_context_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert!(e.to_string().starts_with("step 3"));
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("bad flag {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "bad flag 1");
    }
}
