//! Offline stub of the `xla` (xla-rs) API surface midx touches.
//!
//! The container this repo builds in has no PJRT / libxla, so the runtime
//! half is a stub with the same signatures: [`Literal`] is a fully
//! functional host-side tensor container (the literal helpers and their
//! tests work), while [`PjRtClient::cpu`] returns an error — every consumer
//! (trainer, integration tests) already gates on artifact availability and
//! degrades gracefully. Swapping the real crate back in is a one-line
//! change in the workspace `Cargo.toml`; no midx source changes needed.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT execution is unavailable in this offline build \
             (vendor/xla is a stub; link the real xla-rs crate to run artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + fmt::Debug {
    fn wrap(data: Vec<Self>) -> Elements;
    fn unwrap(e: &Elements) -> Option<&[Self]>;
}

#[derive(Debug, Clone)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elements {
    fn len(&self) -> usize {
        match self {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
        }
    }
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Elements {
        Elements::F32(data)
    }
    fn unwrap(e: &Elements) -> Option<&[f32]> {
        match e {
            Elements::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Elements {
        Elements::I32(data)
    }
    fn unwrap(e: &Elements) -> Option<&[i32]> {
        match e {
            Elements::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side tensor value: typed flat buffer + dims, or a tuple of values.
#[derive(Debug, Clone)]
pub enum Literal {
    Array { data: Elements, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        self.dims.clone()
    }
}

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal::Array { data: T::wrap(data.to_vec()), dims: vec![n] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { data, .. } => {
                let numel: i64 = dims.iter().product();
                if numel as usize != data.len() {
                    return Err(Error::new(format!(
                        "reshape: {} elements into shape {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::Array { data: data.clone(), dims: dims.to_vec() })
            }
            Literal::Tuple(_) => Err(Error::new("reshape on tuple literal")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unwrap(data)
                .map(|s| s.to_vec())
                .ok_or_else(|| Error::new("to_vec: element type mismatch")),
            Literal::Tuple(_) => Err(Error::new("to_vec on tuple literal")),
        }
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.first().copied().ok_or_else(|| Error::new("get_first_element: empty literal"))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(xs) => Ok(xs),
            lit @ Literal::Array { .. } => Ok(vec![lit]),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { dims, .. } => Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) => Err(Error::new("array_shape on tuple literal")),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the real crate).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path}")))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1i32]), Literal::vec1(&[2.0f32])]);
        let xs = t.to_tuple().unwrap();
        assert_eq!(xs.len(), 2);
        // non-tuple decomposes to a singleton (mirrors single-output modules)
        assert_eq!(Literal::vec1(&[1.0f32]).to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn runtime_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
